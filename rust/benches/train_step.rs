//! Bench: end-to-end train-step latency per recipe on the `test` config —
//! the L3 §Perf instrument. Separates PJRT execution from coordinator
//! overhead (all-reduce + clip + AdamW) so the "coordinator <10% of step"
//! target (DESIGN.md §7) is measurable. Substrate measurements and the
//! two timing claims (cached-pack wins, RHT prep < 5% of step) are
//! recorded through the shared reporter into `BENCH_<gitrev>.json`.

#[path = "harness.rs"]
mod harness;

use mxfp4_train::coordinator::{MxWeightCache, Orientation};
use mxfp4_train::gemm::simd::Kernel;
use mxfp4_train::gemm::{mx_gemm_packed, mx_matmul, Mat, MxMode};
use mxfp4_train::hadamard;
use mxfp4_train::mx::pipeline::PackPipeline;
use mxfp4_train::optim::{self, AdamW, ParamRounding};
use mxfp4_train::rng::Rng;
use mxfp4_train::runtime::{executor, Backend, BackendSpec, Executor, Registry};

/// Rust-substrate emulation of the step-level weight path: one weight
/// matrix feeding every microbatch GEMM of a step. Measures what the
/// quantize-once cache (coordinator::mxcache) saves vs re-quantizing the
/// weight per GEMM — runs without artifacts, so the BENCH trajectory
/// captures the packed-engine win in any checkout.
fn substrate_weight_cache_bench(rep: &mut harness::Reporter) {
    // Small microbatches on purpose: the step is weight-dominated, like a
    // decoder layer at inference-ish batch — exactly where re-quantizing
    // W per GEMM hurts most.
    rep.section("rust substrate: quantize-once weight cache (4 microbatches, 32x1024 @ 1024x1024)");
    let mut rng = Rng::seed(7);
    let w = Mat::gaussian(1024, 1024, 0.02, &mut rng);
    let acts: Vec<Mat> = (0..4).map(|_| Mat::gaussian(32, 1024, 1.0, &mut rng)).collect();
    let flops = 4.0 * 2.0 * 32.0 * 1024.0 * 1024.0;

    let t_qdq = rep.bench("qdq_requant_x4", flops, "flop", 0, 2, || {
        for act in &acts {
            std::hint::black_box(mx_matmul(act, &w, MxMode::Nr, 64, &mut Rng::seed(1), 4));
        }
    });

    let t_nocache = rep.bench("packed_repack_per_gemm", flops, "flop", 0, 2, || {
        for act in &acts {
            // fused Transposed gather — still wasteful (once per GEMM),
            // but no materialized Wᵀ even in the baseline
            let pw = PackPipeline::transposed(&w.data, 1024, 1024).pack_nr(4);
            let pact = act.pack_nr();
            std::hint::black_box(mx_gemm_packed(&pact, &pw, 4));
        }
    });

    let mut cache = MxWeightCache::new(1);
    let mut epoch = 0u64;
    let t_cached = rep.bench("packed_weight_cache", flops, "flop", 0, 2, || {
        epoch += 1;
        cache.advance(epoch); // optimizer "updated" W: new step, one fresh pack
        for act in &acts {
            let pw = cache.pack_nr(0, &w.data, 1024, 1024, Orientation::Transposed, 4);
            let pact = act.pack_nr();
            std::hint::black_box(mx_gemm_packed(&pact, pw, 4));
        }
    });

    println!(
        "cache accounting: {} packs, {} hits; step-level speedup over per-GEMM repack: {:.2}x \
         (vs qdq requantize: {:.2}x)",
        cache.packs,
        cache.hits,
        t_nocache / t_cached,
        t_qdq / t_cached
    );
    // With prep fused, the re-pack delta is a small slice of a GEMM-
    // dominated step, so the step-level ratio above is reported rather
    // than asserted (it sits inside timing noise). The cache's actual
    // claim — pay 1 weight pack per step instead of 4 — is asserted on
    // prep-only timings, where the 4x work gap dwarfs noise.
    let elems = 1024.0 * 1024.0;
    let t_prep_4x = rep.bench("prep_pack_x4", 4.0 * elems, "elem", 1, 3, || {
        for _ in 0..4 {
            std::hint::black_box(PackPipeline::transposed(&w.data, 1024, 1024).pack_nr(4));
        }
    });
    let t_prep_1x = rep.bench("prep_pack_1x_cache_fill", elems, "elem", 1, 3, || {
        std::hint::black_box(PackPipeline::transposed(&w.data, 1024, 1024).pack_nr(4));
    });
    rep.gate_min("cached_pack_over_4x", t_prep_4x / t_prep_1x, 1.0);
}

/// §4.2's overhead budget, instrumented: the random Hadamard transform
/// must stay "<5% of training step time". With prep fused into the pack
/// pipeline, the RHT increment is directly measurable as
/// (fused RHT pack − plain pack) on paper-scale 2048×1024 operands of a
/// 2048×1024×2048 GEMM; the step cost it amortizes against is that GEMM
/// plus both operand packs. Asserted, not just printed — a regression
/// that un-fuses the transform (or fattens it past the budget) fails
/// the bench. Also reports the end-to-end native-step delta
/// (mxfp4_rht_sr vs mxfp4_sr) for the tiny test config, where GEMMs
/// are far too small to amortize anything — report-only, since §4.2's
/// claim is about real model shapes.
fn rht_prep_share_bench(rep: &mut harness::Reporter) {
    // operand shapes chosen GEMM-heavy the way real layers are: prep
    // cost scales with (m + n)·k elements, the GEMM with m·n·k
    rep.section("§4.2 RHT prep overhead (fused pipeline, 2048x1024 operands, g=32)");
    let (m, k) = (2048usize, 1024usize);
    let mut rng = Rng::seed(11);
    let a = Mat::gaussian(m, k, 1.0, &mut rng);
    let bt = Mat::gaussian(m, k, 1.0, &mut rng);
    let sign = hadamard::sample_sign(32, &mut Rng::seed(12));
    let elems = (m * k) as f64;
    let t_plain = rep.bench("fused_pack_no_rht", elems, "elem", 1, 3, || {
        std::hint::black_box(PackPipeline::new(&a.data, m, k).pack_nr(4));
    });
    let t_rht = rep.bench("fused_pack_rht_g32", elems, "elem", 1, 3, || {
        std::hint::black_box(PackPipeline::new(&a.data, m, k).with_rht(&sign).pack_nr(4));
    });
    let pa = PackPipeline::new(&a.data, m, k).with_rht(&sign).pack_nr(4);
    let pbt = PackPipeline::new(&bt.data, m, k).with_rht(&sign).pack_nr(4);
    let gemm_flops = 2.0 * (m * m * k) as f64;
    let t_gemm = rep.bench("packed_gemm_2048", gemm_flops, "flop", 1, 1, || {
        std::hint::black_box(mx_gemm_packed(&pa, &pbt, 4));
    });
    let rht_prep = 2.0 * (t_rht - t_plain).max(0.0); // both GEMM operands
    let step = t_gemm + 2.0 * t_rht;
    let share = rht_prep / step;
    println!(
        "RHT prep share of GEMM + operand prep: {:.2}% (paper target < 5%)",
        share * 100.0
    );
    rep.gate_max("rht_prep_share_of_step", share, 0.05);

    // end-to-end tiny-config delta (report-only; see the doc comment)
    let step_secs = |recipe: &str| {
        let spec = BackendSpec::native("test", recipe, None).unwrap();
        let mut backend = spec.connect().unwrap();
        let params = executor::init_params_for(&spec.param_specs(), spec.n_layers(), 0);
        let n = backend.tokens_per_step();
        let v = backend.vocab() as i32;
        let tokens: Vec<i32> = (0..n as i32).map(|i| i % v).collect();
        let labels: Vec<i32> = (0..n as i32).map(|i| (i + 1) % v).collect();
        let mut seed = 0u32;
        harness::time_secs(1, 5, || {
            seed += 1;
            std::hint::black_box(backend.train_step(seed, &tokens, &labels, &params).unwrap());
        })
    };
    let (t_sr, t_rht_sr) = (step_secs("mxfp4_sr"), step_secs("mxfp4_rht_sr_g32"));
    println!(
        "native test-config step delta rht_sr vs sr: {:.1}% (tiny GEMMs — not the §4.2 regime)",
        100.0 * (t_rht_sr - t_sr).max(0.0) / t_rht_sr
    );
}

/// Native-backend step latency per recipe: the end-to-end cost of the
/// hand-written forward/backward with every linear GEMM routed through
/// the MX engine — runs in any checkout (no artifacts, no PJRT).
fn native_backend_bench(rep: &mut harness::Reporter) {
    rep.section("native backend train step by recipe (test config, batch 4 x seq 32)");
    println!("packed GEMM inner kernel: {}", Kernel::select().name());
    for recipe in ["bf16", "mxfp4", "mxfp4_sr", "mxfp4_rht", "mxfp4_rht_sr"] {
        let spec = BackendSpec::native("test", recipe, None).unwrap();
        let mut backend = spec.connect().unwrap();
        let params = executor::init_params_for(&spec.param_specs(), spec.n_layers(), 0);
        let n = backend.tokens_per_step();
        let v = backend.vocab() as i32;
        let tokens: Vec<i32> = (0..n as i32).map(|i| i % v).collect();
        let labels: Vec<i32> = (0..n as i32).map(|i| (i + 1) % v).collect();
        let mut seed = 0u32;
        rep.bench(&format!("native_train_step_{recipe}"), n as f64, "tok", 1, 5, || {
            seed += 1;
            std::hint::black_box(backend.train_step(seed, &tokens, &labels, &params).unwrap());
        });
    }
}

fn main() {
    let mut rep = harness::Reporter::start("train_step");
    substrate_weight_cache_bench(&mut rep);
    rht_prep_share_bench(&mut rep);
    native_backend_bench(&mut rep);

    if !executor::backend_available() {
        println!("skipping PJRT train_step bench: stub xla backend (see rust/vendor/xla)");
        rep.finish_and_assert();
        return;
    }
    let reg = match Registry::open(&mxfp4_train::runtime::default_artifacts_dir()) {
        Ok(r) => r,
        Err(e) => {
            println!("skipping PJRT train_step bench: {e} (run `make artifacts`)");
            rep.finish_and_assert();
            return;
        }
    };

    harness::header("train-step latency by recipe (test config, batch 4 x seq 32)");
    for recipe in ["bf16", "mxfp4", "mxfp4_sr", "mxfp4_rht", "mxfp4_rht_sr"] {
        let Some(art) = reg.find("test", recipe, "train") else { continue };
        let exe = Executor::compile_cpu(art).unwrap();
        let params = executor::init_params(art, 0);
        let n = art.tokens_per_step();
        let tokens: Vec<i32> = (0..n as i32).map(|i| i % 251).collect();
        let labels: Vec<i32> = (0..n as i32).map(|i| (i + 1) % 251).collect();
        let mut seed = 0u32;
        harness::bench(&format!("pjrt train_step [{recipe}]"), n as f64, "tok", 1, 5, || {
            seed += 1;
            std::hint::black_box(exe.train_step(seed, &tokens, &labels, &params).unwrap());
        });
    }

    harness::header("coordinator-side cost (grad clip + AdamW fused update)");
    let art = reg.find("test", "bf16", "train").unwrap();
    let exe = Executor::compile_cpu(art).unwrap();
    let params = executor::init_params(art, 0);
    let names: Vec<String> = art.params.iter().map(|p| p.name.clone()).collect();
    let n = art.tokens_per_step();
    let tokens: Vec<i32> = (0..n as i32).map(|i| i % 251).collect();
    let labels: Vec<i32> = (0..n as i32).map(|i| (i + 1) % 251).collect();
    let out = exe.train_step(1, &tokens, &labels, &params).unwrap();
    let nparams: usize = params.iter().map(Vec::len).sum();

    let mut opt = AdamW::new(&params, &names, 0.9, 0.95, 1e-8, 0.01, ParamRounding::Nearest, 0);
    let mut compute = params.clone();
    let t_opt = harness::bench("clip + adamw step", nparams as f64, "param", 1, 10, || {
        let mut grads = out.grads.clone();
        optim::clip_global_norm(&mut grads, 1.0, 4);
        opt.step(&grads, 1e-3, &mut compute);
    });
    let t_step = harness::time_secs(1, 5, || {
        std::hint::black_box(exe.train_step(2, &tokens, &labels, &params).unwrap());
    });
    println!(
        "coordinator share of a bf16 step: {:.1}% (target < 10%)",
        100.0 * t_opt / (t_opt + t_step)
    );
    rep.finish_and_assert();
}
