//! Bench: end-to-end train-step latency per recipe on the `test` config —
//! the L3 §Perf instrument. Separates PJRT execution from coordinator
//! overhead (all-reduce + clip + AdamW) so the "coordinator <10% of step"
//! target (DESIGN.md §7) is measurable.

#[path = "harness.rs"]
mod harness;

use mxfp4_train::optim::{self, AdamW, ParamRounding};
use mxfp4_train::runtime::{executor, Executor, Registry};

fn main() {
    let reg = match Registry::open(&mxfp4_train::runtime::default_artifacts_dir()) {
        Ok(r) => r,
        Err(e) => {
            println!("skipping train_step bench: {e} (run `make artifacts`)");
            return;
        }
    };

    harness::header("train-step latency by recipe (test config, batch 4 x seq 32)");
    for recipe in ["bf16", "mxfp4", "mxfp4_sr", "mxfp4_rht", "mxfp4_rht_sr"] {
        let Some(art) = reg.find("test", recipe, "train") else { continue };
        let exe = Executor::compile_cpu(art).unwrap();
        let params = executor::init_params(art, 0);
        let n = art.tokens_per_step();
        let tokens: Vec<i32> = (0..n as i32).map(|i| i % 251).collect();
        let labels: Vec<i32> = (0..n as i32).map(|i| (i + 1) % 251).collect();
        let mut seed = 0u32;
        harness::bench(&format!("pjrt train_step [{recipe}]"), n as f64, "tok", 1, 5, || {
            seed += 1;
            std::hint::black_box(exe.train_step(seed, &tokens, &labels, &params).unwrap());
        });
    }

    harness::header("coordinator-side cost (grad clip + AdamW fused update)");
    let art = reg.find("test", "bf16", "train").unwrap();
    let exe = Executor::compile_cpu(art).unwrap();
    let params = executor::init_params(art, 0);
    let names: Vec<String> = art.params.iter().map(|p| p.name.clone()).collect();
    let n = art.tokens_per_step();
    let tokens: Vec<i32> = (0..n as i32).map(|i| i % 251).collect();
    let labels: Vec<i32> = (0..n as i32).map(|i| (i + 1) % 251).collect();
    let out = exe.train_step(1, &tokens, &labels, &params).unwrap();
    let nparams: usize = params.iter().map(Vec::len).sum();

    let mut opt = AdamW::new(&params, &names, 0.9, 0.95, 1e-8, 0.01, ParamRounding::Nearest, 0);
    let mut compute = params.clone();
    let t_opt = harness::bench("clip + adamw step", nparams as f64, "param", 1, 10, || {
        let mut grads = out.grads.clone();
        optim::clip_global_norm(&mut grads, 1.0, 4);
        opt.step(&grads, 1e-3, &mut compute);
    });
    let t_step = harness::time_secs(1, 5, || {
        std::hint::black_box(exe.train_step(2, &tokens, &labels, &params).unwrap());
    });
    println!(
        "coordinator share of a bf16 step: {:.1}% (target < 10%)",
        100.0 * t_opt / (t_opt + t_step)
    );
}
