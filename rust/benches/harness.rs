//! Shared micro-bench harness (criterion is unavailable offline).
//!
//! `cargo bench` runs each `[[bench]]` target's `main()`; targets use
//! `bench()` to time closures with warmup + median-of-means and print
//! aligned rows. Compiled as a module into each bench via `#[path]`.

use std::time::Instant;

/// Median-of-means seconds/iteration with warmup.
pub fn time_secs<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let reps = 3usize;
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        times.push(t.elapsed().as_secs_f64() / iters.max(1) as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[reps / 2]
}

/// Time and print one row: label, secs/iter, and a derived rate.
pub fn bench<F: FnMut()>(label: &str, units: f64, unit_name: &str, warmup: usize, iters: usize, f: F) -> f64 {
    let secs = time_secs(warmup, iters, f);
    println!(
        "{label:<44} {:>12.3} us/iter {:>14.2} {unit_name}/s",
        secs * 1e6,
        units / secs
    );
    secs
}

pub fn header(title: &str) {
    println!("\n==== {title} ====");
}
