//! Shared micro-bench harness (criterion is unavailable offline).
//!
//! Since PR 10 this is a thin shim over [`mxfp4_train::obs::bench`]:
//! the timing loop (warmup + reps + median/MAD), the aligned-row
//! printer, and the [`Reporter`] that records named measurements and
//! data-driven gates into the schema-versioned `BENCH_<gitrev>.json`
//! report all live in the library, shared with the `bench` CLI
//! subcommand. Compiled as a module into each bench via `#[path]`.
//!
//! Bench targets construct a [`Reporter`] per suite, replace bare
//! timing `assert!`s with `gate_min`/`gate_max` (recorded in the
//! report, still fatal via [`Reporter::finish_and_assert`]), and keep
//! correctness assertions (byte parity, allocation counts, exactness)
//! as plain asserts.

#[allow(unused_imports)]
pub use mxfp4_train::obs::bench::Reporter;

/// Median seconds/iteration with warmup (3 reps, back-compat helper
/// for unrecorded side measurements).
#[allow(dead_code)]
pub fn time_secs<F: FnMut()>(warmup: usize, iters: usize, f: F) -> f64 {
    mxfp4_train::obs::bench::time_secs(warmup, iters, f)
}

/// Time and print one row without recording it in a report.
#[allow(dead_code)]
pub fn bench<F: FnMut()>(
    label: &str,
    units: f64,
    unit_name: &str,
    warmup: usize,
    iters: usize,
    f: F,
) -> f64 {
    let secs = time_secs(warmup, iters, f);
    println!(
        "{label:<44} {:>12.3} us/iter {:>14.2} {unit_name}/s",
        secs * 1e6,
        units / secs
    );
    secs
}

#[allow(dead_code)]
pub fn header(title: &str) {
    println!("\n==== {title} ====");
}
