//! Bench: Fig. 2 regeneration — SR-GEMM variance vs b, with/without RHT
//! (DESIGN.md F2). Prints the figure's series and keeps the Theorem 3.2
//! growth-rate ordering as a hard assert (a statistical-correctness
//! contract, not a perf number); mx_matmul timings are recorded into
//! `BENCH_<gitrev>.json` through the shared reporter.

#[path = "harness.rs"]
mod harness;

use mxfp4_train::gemm::{mx_matmul, Mat, MxMode};
use mxfp4_train::rng::Rng;

fn variance_point(b: usize, p: f64, samples: usize, trials: usize) -> (f64, f64) {
    let mut rng = Rng::seed(0xF16 ^ b as u64);
    let mut sum = [0.0f64; 2];
    for s in 0..samples {
        let a = Mat::gaussian_outliers(1, b, p, 5.0, &mut rng);
        let x = Mat::gaussian_outliers(b, 1, p, 5.0, &mut rng);
        for (i, mode) in [MxMode::Sr, MxMode::RhtSr].into_iter().enumerate() {
            let vals: Vec<f64> = (0..trials)
                .map(|t| {
                    mx_matmul(&a, &x, mode, 32, &mut Rng::seed((s * 100 + t) as u64), 1).data[0]
                        as f64
                })
                .collect();
            let mean = vals.iter().sum::<f64>() / trials as f64;
            sum[i] += vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / (trials - 1) as f64;
        }
    }
    (sum[0] / samples as f64, sum[1] / samples as f64)
}

fn main() {
    let mut rep = harness::Reporter::start("variance");
    rep.section("Fig. 2: SR-GEMM variance vs b (A,B ~ N(0,I) + Bern(p) N(0,5I))");
    let (samples, trials) = (96, 16);
    for p in [0.0, 0.01] {
        println!("\np = {p}");
        println!("{:>6} {:>14} {:>14} {:>7}", "b", "var no-RHT", "var RHT", "ratio");
        let mut prev = (0.0, 0.0);
        let mut growth = (0.0, 0.0);
        for (i, b) in [128usize, 512, 2048].into_iter().enumerate() {
            let (vp, vr) = variance_point(b, p, samples, trials);
            println!("{b:>6} {vp:>14.5} {vr:>14.5} {:>7.2}", vp / vr.max(1e-12));
            if i > 0 {
                growth = (vp / prev.0, vr / prev.1);
            }
            prev = (vp, vr);
        }
        // Theorem 3.2: variance grows slower with the RHT
        assert!(
            growth.1 < growth.0,
            "RHT variance growth {} must be below no-RHT {}",
            growth.1,
            growth.0
        );
    }

    rep.section("mx_matmul wall time (128x1024 @ 1024x128)");
    let mut rng = Rng::seed(7);
    let a = Mat::gaussian(128, 1024, 1.0, &mut rng);
    let b = Mat::gaussian(1024, 128, 1.0, &mut rng);
    let flops = 2.0 * 128.0 * 1024.0 * 128.0;
    for (label, mode) in [
        ("exact", MxMode::Exact),
        ("nr", MxMode::Nr),
        ("sr", MxMode::Sr),
        ("rht_g64", MxMode::Rht),
        ("rht_sr_g64", MxMode::RhtSr),
    ] {
        rep.bench(&format!("mx_matmul_{label}"), flops, "flop", 1, 3, || {
            std::hint::black_box(mx_matmul(&a, &b, mode, 64, &mut Rng::seed(1), 4));
        });
    }

    rep.finish_and_assert();
}
