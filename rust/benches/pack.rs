//! Bench: fused operand-prep pipeline vs. the old materialize-then-
//! quantize path (ISSUE 4 / ROADMAP "Fused RHT-in-pack").
//!
//! Two assertions, both load-bearing:
//!
//! 1. **Zero intermediate matrices.** A counting global allocator tracks
//!    every allocation at least half the source-matrix size during the
//!    fused pack. The old path makes two (the clone/transpose scratch
//!    and, on the qdq path, nothing smaller); the pipeline must make
//!    *none* — its only large allocation is the packed output itself,
//!    which at 4.25 bits/element sits far below the threshold.
//! 2. **The fused RHT pack wins.** Same transform, same rounding, same
//!    bytes out — strictly less memory traffic (one pass, no scratch
//!    matrix), so fused must beat materialized at equal worker count,
//!    and scale with workers on top (the old quantize loop was
//!    single-threaded).
//!
//! The allocation and byte-parity checks stay hard asserts
//! (correctness contracts); the timing wins are recorded as data-driven
//! gates in `BENCH_<gitrev>.json` via the shared reporter.

#[path = "harness.rs"]
mod harness;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use mxfp4_train::gemm::{transpose_flat, Mat};
use mxfp4_train::hadamard;
use mxfp4_train::mx::mat::MxMat;
use mxfp4_train::mx::pipeline::PackPipeline;
use mxfp4_train::rng::Rng;

/// System allocator wrapper that counts allocations of at least
/// `THRESHOLD` bytes — cheap enough to leave on for the whole bench.
struct CountingAlloc;

static LARGE_ALLOCS: AtomicUsize = AtomicUsize::new(0);
static THRESHOLD: AtomicUsize = AtomicUsize::new(usize::MAX);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if layout.size() >= THRESHOLD.load(Ordering::Relaxed) {
            LARGE_ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Run `f` while counting allocations of >= `threshold` bytes.
fn count_large_allocs(threshold: usize, f: impl FnOnce()) -> usize {
    THRESHOLD.store(threshold, Ordering::Relaxed);
    LARGE_ALLOCS.store(0, Ordering::Relaxed);
    f();
    let n = LARGE_ALLOCS.load(Ordering::Relaxed);
    THRESHOLD.store(usize::MAX, Ordering::Relaxed);
    n
}

fn main() {
    let mut rep = harness::Reporter::start("pack");
    const N: usize = 1024;
    let mut rng = Rng::seed(3);
    let w = Mat::gaussian(N, N, 1.0, &mut rng);
    let sign = hadamard::sample_sign(32, &mut rng);
    let elems = (N * N) as f64;
    let matrix_bytes = N * N * std::mem::size_of::<f32>();

    // -- allocation accounting -------------------------------------------
    rep.section("operand-prep allocations (>= half a 1024x1024 f32 matrix counts)");
    let thresh = matrix_bytes / 2;
    let mat_allocs = count_large_allocs(thresh, || {
        // the old path: materialize Wᵀ, transform it, quantize the copy
        let mut wt = transpose_flat(&w.data, N, N);
        hadamard::rht_blockwise_dense(&mut wt, &sign, 4);
        std::hint::black_box(MxMat::quantize_nr(&wt, N, N));
    });
    let fused_allocs = count_large_allocs(thresh, || {
        std::hint::black_box(
            PackPipeline::transposed(&w.data, N, N).with_rht(&sign).pack_nr(4),
        );
    });
    println!("materialized prep: {mat_allocs} matrix-sized allocations; fused: {fused_allocs}");
    assert!(mat_allocs >= 1, "reference path should materialize at least one matrix");
    assert_eq!(fused_allocs, 0, "fused pipeline must allocate no intermediate matrix");

    // -- fused vs materialized timing ------------------------------------
    rep.section("fused RHT pack vs materialized prep (1024x1024, Transposed + RHT g=32)");
    let t_mat = rep.bench("materialized_transpose_rht_quant", elems, "elem", 1, 3, || {
        let mut wt = transpose_flat(&w.data, N, N);
        hadamard::rht_blockwise_dense(&mut wt, &sign, 1);
        std::hint::black_box(MxMat::quantize_nr(&wt, N, N));
    });
    let t_fused_1 = rep.bench("fused_pipeline_1w", elems, "elem", 1, 3, || {
        std::hint::black_box(
            PackPipeline::transposed(&w.data, N, N).with_rht(&sign).pack_nr(1),
        );
    });
    let t_fused_4 = rep.bench("fused_pipeline_4w", elems, "elem", 1, 3, || {
        std::hint::black_box(
            PackPipeline::transposed(&w.data, N, N).with_rht(&sign).pack_nr(4),
        );
    });
    println!(
        "fused speedup over materialized prep: {:.2}x (1 worker), {:.2}x (4 workers)",
        t_mat / t_fused_1,
        t_mat / t_fused_4
    );
    rep.gate_min("fused_vs_materialized_1w", t_mat / t_fused_1, 1.0);

    // -- SR: fast-forward stream split cost ------------------------------
    rep.section("SR pack (dither fast-forward split), 1024x1024 AsStored");
    let t_sr_mat = rep.bench("sr_materialized_clone_rht_quant", elems, "elem", 1, 3, || {
        let mut c = w.data.clone();
        hadamard::rht_blockwise_dense(&mut c, &sign, 1);
        std::hint::black_box(MxMat::quantize_sr(&c, N, N, &mut Rng::seed(5)));
    });
    let t_sr_1 = rep.bench("sr_fused_pipeline_1w", elems, "elem", 1, 3, || {
        let mut r = Rng::seed(5);
        std::hint::black_box(PackPipeline::new(&w.data, N, N).with_rht(&sign).pack_sr(&mut r, 1));
    });
    let t_sr_8 = rep.bench("sr_fused_pipeline_8w", elems, "elem", 1, 3, || {
        let mut r = Rng::seed(5);
        std::hint::black_box(PackPipeline::new(&w.data, N, N).with_rht(&sign).pack_sr(&mut r, 8));
    });
    println!(
        "fused SR speedup over materialized prep: {:.2}x (1 worker), {:.2}x (8 workers)",
        t_sr_mat / t_sr_1,
        t_sr_mat / t_sr_8
    );
    rep.gate_min("sr_fused_vs_materialized_1w", t_sr_mat / t_sr_1, 1.0);

    // byte-parity spot check under bench shapes (the full matrix lives in
    // tests/packed_gemm.rs)
    let mut wt = transpose_flat(&w.data, N, N);
    hadamard::rht_blockwise_dense(&mut wt, &sign, 1);
    let want = MxMat::quantize_sr(&wt, N, N, &mut Rng::seed(9));
    let got = PackPipeline::transposed(&w.data, N, N).with_rht(&sign).pack_sr(&mut Rng::seed(9), 8);
    assert_eq!(got, want, "fused and materialized packs must be byte-identical");
    println!("byte parity: fused == materialized at 1024x1024 (RHT+SR, 8 workers)");

    rep.finish_and_assert();
}
