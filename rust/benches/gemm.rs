//! Bench: the rust GEMM substrate (threaded scaling + MX-mode costs), the
//! packed MXFP4 tensor engine vs the seed per-block path, and the
//! quantize-once weight-reuse win — supports the Fig. 2 / Table 5
//! harnesses and the §1 "MXFP4 GEMMs are cheap" narrative.
//!
//! Measurements and the two perf gates (>=3x packed-vs-seed, >=2x SIMD
//! shuffle-LUT) are recorded into `BENCH_<gitrev>.json` via the shared
//! reporter; a failed gate still fails `cargo bench` at exit.

#[path = "harness.rs"]
mod harness;

use mxfp4_train::gemm::simd::Kernel;
use mxfp4_train::gemm::{matmul, mx_gemm_packed, mx_gemm_packed_with, mx_matmul, Mat, MxMode};
use mxfp4_train::mx::block::MxVec;
use mxfp4_train::mx::mat::MxMat;
use mxfp4_train::mx::pipeline::PackPipeline;
use mxfp4_train::rng::Rng;

fn main() {
    let mut r = harness::Reporter::start("gemm");
    let mut rng = Rng::seed(0);
    let a = Mat::gaussian(256, 1024, 1.0, &mut rng);
    let b = Mat::gaussian(1024, 256, 1.0, &mut rng);
    let flops = 2.0 * 256.0 * 1024.0 * 256.0;

    r.section("f32 GEMM thread scaling (256x1024x256)");
    let mut t1 = 0.0;
    for w in [1usize, 2, 4, 8] {
        let t = r.bench(&format!("f32_gemm_workers_{w}"), flops, "flop", 1, 3, || {
            std::hint::black_box(matmul(&a, &b, w));
        });
        if w == 1 {
            t1 = t;
        }
    }
    println!("(speedup at 8 workers: {:.2}x over 1)", t1 / {
        harness::time_secs(0, 3, || {
            std::hint::black_box(matmul(&a, &b, 8));
        })
    });

    r.section("MX GEMM modes, qdq reference path (256x1024x256, g=64)");
    for (label, mode) in [
        ("exact", MxMode::Exact),
        ("nr", MxMode::Nr),
        ("rht_sr", MxMode::RhtSr),
    ] {
        r.bench(&format!("mx_matmul_{label}"), flops, "flop", 1, 3, || {
            std::hint::black_box(mx_matmul(&a, &b, mode, 64, &mut Rng::seed(1), 4));
        });
    }

    // ---------------------------------------------------------------
    // The tentpole claim: the packed LUT engine vs the seed per-block
    // MxVec::dot path, kernel against kernel at 1024^3 (1 worker each).
    // ---------------------------------------------------------------
    r.section("packed LUT engine vs seed per-block path (1024^3, NR)");
    let (m, n, k) = (1024usize, 1024usize, 1024usize);
    let aw = Mat::gaussian(m, k, 1.0, &mut rng);
    let bw = Mat::gaussian(n, k, 1.0, &mut rng); // already Bᵀ-shaped
    let big_flops = 2.0 * (m * n * k) as f64;

    let qa_rows: Vec<MxVec> = (0..m).map(|r| MxVec::quantize_nr(aw.row(r))).collect();
    let qb_rows: Vec<MxVec> = (0..n).map(|r| MxVec::quantize_nr(bw.row(r))).collect();
    let t_seed = r.bench("seed_mxvec_dot_1w", big_flops, "flop", 0, 1, || {
        let mut c = Mat::zeros(m, n);
        for r in 0..m {
            let qr = &qa_rows[r];
            for (j, qj) in qb_rows.iter().enumerate() {
                c.data[r * n + j] = qr.dot(qj);
            }
        }
        std::hint::black_box(&c);
    });

    let pa = aw.pack_nr();
    let pbt = bw.pack_nr();
    let t_packed = r.bench("packed_lut_1w", big_flops, "flop", 1, 1, || {
        std::hint::black_box(mx_gemm_packed(&pa, &pbt, 1));
    });
    r.bench("packed_lut_8w", big_flops, "flop", 0, 1, || {
        std::hint::black_box(mx_gemm_packed(&pa, &pbt, 8));
    });
    r.gate_min("packed_vs_seed_speedup", t_seed / t_packed, 3.0);

    // ---------------------------------------------------------------
    // ISSUE 6 gate: the SIMD shuffle-LUT kernel vs the scalar row_dot
    // oracle, same packed operands, kernel against kernel at 1024^3.
    // Outputs are bit-identical (tests/packed_gemm.rs); this section
    // pins the *speed* half of the contract.
    // ---------------------------------------------------------------
    r.section("SIMD shuffle-LUT kernel vs scalar row_dot (1024^3, NR, 1 worker)");
    println!("dispatched inner kernel: {}", Kernel::select().name());
    match Kernel::simd() {
        None => {
            println!(
                "no SIMD ISA on this host (need SSSE3 or NEON); \
                 skipping the >=2x shuffle-LUT gate — scalar kernel is the active path"
            );
        }
        Some(simd) => {
            let t_scalar = r.bench("packed_scalar_oracle", big_flops, "flop", 1, 1, || {
                std::hint::black_box(mx_gemm_packed_with(&pa, &pbt, 1, Kernel::Scalar));
            });
            let t_simd = r.bench("packed_simd_kernel", big_flops, "flop", 1, 1, || {
                std::hint::black_box(mx_gemm_packed_with(&pa, &pbt, 1, simd));
            });
            r.gate_min("simd_speedup", t_scalar / t_simd, 2.0);
        }
    }

    // ---------------------------------------------------------------
    // Quantize-once: one weight feeding several GEMMs per step. The qdq
    // path re-quantizes W inside every call; the packed engine pays for
    // W once and re-packs only the activations (coordinator::mxcache).
    // ---------------------------------------------------------------
    r.section("quantize-once weight reuse (8 GEMMs over one weight, 256x1024x256)");
    let reuse = 8usize;
    let t_requant =
        r.bench("qdq_requant_x8", reuse as f64 * flops, "flop", 0, 1, || {
            for _ in 0..reuse {
                std::hint::black_box(mx_matmul(&a, &b, MxMode::Nr, 64, &mut Rng::seed(1), 4));
            }
        });
    let t_once =
        r.bench("pack_once_x8", reuse as f64 * flops, "flop", 0, 1, || {
            let pw = PackPipeline::transposed(&b.data, 256, 1024).pack_nr(4); // once per step
            for _ in 0..reuse {
                let pact = a.pack_nr(); // activations change per GEMM
                std::hint::black_box(mx_gemm_packed(&pact, &pw, 4));
            }
        });
    println!("quantize-once speedup over per-GEMM requantize: {:.2}x", t_requant / t_once);

    r.section("packed MX dot product (32K elements)");
    let mut x = vec![0.0f32; 1 << 15];
    let mut y = vec![0.0f32; 1 << 15];
    rng.fill_normal(&mut x, 1.0);
    rng.fill_normal(&mut y, 1.0);
    let qx = MxVec::quantize_nr(&x);
    let qy = MxVec::quantize_nr(&y);
    r.bench("mxvec_dot_32k", x.len() as f64, "elem", 2, 20, || {
        std::hint::black_box(qx.dot(&qy));
    });
    let px = MxMat::quantize_nr(&x, 1, x.len());
    let py = MxMat::quantize_nr(&y, 1, y.len());
    r.bench("mxmat_row_dot_32k", x.len() as f64, "elem", 2, 20, || {
        std::hint::black_box(px.row_dot(0, &py, 0));
    });

    r.finish_and_assert();
}
