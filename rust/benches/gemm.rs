//! Bench: the rust GEMM substrate (threaded scaling + MX-mode costs) and
//! the packed MX dot product — supports the Fig. 2 / Table 5 harnesses.

#[path = "harness.rs"]
mod harness;

use mxfp4_train::gemm::{matmul, mx_matmul, Mat, MxMode};
use mxfp4_train::mx::block::MxVec;
use mxfp4_train::rng::Rng;

fn main() {
    let mut rng = Rng::seed(0);
    let a = Mat::gaussian(256, 1024, 1.0, &mut rng);
    let b = Mat::gaussian(1024, 256, 1.0, &mut rng);
    let flops = 2.0 * 256.0 * 1024.0 * 256.0;

    harness::header("f32 GEMM thread scaling (256x1024x256)");
    let mut t1 = 0.0;
    for w in [1usize, 2, 4, 8] {
        let t = harness::bench(&format!("gemm workers={w}"), flops, "flop", 1, 3, || {
            std::hint::black_box(matmul(&a, &b, w));
        });
        if w == 1 {
            t1 = t;
        }
    }
    println!("(speedup at 8 workers: {:.2}x over 1)", t1 / {
        harness::time_secs(0, 3, || {
            std::hint::black_box(matmul(&a, &b, 8));
        })
    });

    harness::header("MX GEMM modes (256x1024x256, g=64)");
    for (label, mode) in [
        ("exact", MxMode::Exact),
        ("nr", MxMode::Nr),
        ("rht_sr", MxMode::RhtSr),
    ] {
        harness::bench(&format!("mx_matmul {label}"), flops, "flop", 1, 3, || {
            std::hint::black_box(mx_matmul(&a, &b, mode, 64, &mut Rng::seed(1), 4));
        });
    }

    harness::header("packed MX dot product (32K elements)");
    let mut x = vec![0.0f32; 1 << 15];
    let mut y = vec![0.0f32; 1 << 15];
    rng.fill_normal(&mut x, 1.0);
    rng.fill_normal(&mut y, 1.0);
    let qx = MxVec::quantize_nr(&x);
    let qy = MxVec::quantize_nr(&y);
    harness::bench("MxVec::dot", x.len() as f64, "elem", 2, 20, || {
        std::hint::black_box(qx.dot(&qy));
    });
}
