//! Checkpoint-format bench: MXFP4-at-rest (`.mxpk`) vs f32 (`.mxck`).
//!
//! Gates the PR's two perf claims for the `small` preset, asserting (so
//! `cargo bench --bench ckpt` fails loudly if a refactor regresses them):
//!   * size: the packed checkpoint is >= 3x smaller than the f32 one
//!   * cold start: `ServeModel::load_packed` is >= 5x faster than the
//!     f32 load-then-pack path (`checkpoint::load` + `ServeModel::new`)
//!
//! Both gates are data-driven records in `BENCH_<gitrev>.json` now;
//! failure still exits nonzero via the reporter.

#[path = "harness.rs"]
mod harness;

use mxfp4_train::coordinator::checkpoint;
use mxfp4_train::model::{GPTConfig, NativeRecipe};
use mxfp4_train::mx::store;
use mxfp4_train::runtime::executor::init_params_for;
use mxfp4_train::serve::ServeModel;

fn main() {
    let mut rep = harness::Reporter::start("ckpt");
    rep.section("checkpoint formats: small preset, mxfp4 recipe");
    let dir = std::env::temp_dir().join("mxfp4_bench_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let (cfg, _) = GPTConfig::preset("small").unwrap();
    let recipe = NativeRecipe::parse("mxfp4").unwrap();
    let specs = cfg.param_specs();
    let names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
    let params = init_params_for(&specs, cfg.n_layers, 7);
    let workers = mxfp4_train::util::threadpool::default_workers();

    let f32_path = dir.join("master.mxck");
    let pk_path = dir.join("packed.mxpk");
    checkpoint::save(&f32_path, &names, &params).unwrap();
    let pk = checkpoint::build_packed(&cfg, &recipe, &names, &params, workers).unwrap();
    store::write(&pk_path, &pk).unwrap();

    let f32_bytes = std::fs::metadata(&f32_path).unwrap().len();
    let pk_bytes = std::fs::metadata(&pk_path).unwrap().len();
    let ratio = f32_bytes as f64 / pk_bytes as f64;
    println!(
        "{:<44} {f32_bytes:>12} B -> {pk_bytes:>10} B   ({ratio:.2}x smaller)",
        "size: .mxck -> .mxpk"
    );

    // cold start: disk -> servable model (the pack work dominates the
    // f32 path; the packed path is pure section reads)
    let s_f32 = rep.bench("cold_start_f32_load_pack", 1.0, "load", 1, 3, || {
        let (_, tensors) = checkpoint::load(&f32_path).unwrap();
        let m = ServeModel::new(cfg.clone(), recipe.clone(), tensors).unwrap();
        assert!(m.pack_stats() > 0);
        std::hint::black_box(&m);
    });
    let s_pk = rep.bench("cold_start_packed_load", 1.0, "load", 1, 3, || {
        let m = ServeModel::load_packed(&pk_path).unwrap();
        assert_eq!(m.pack_stats(), 0, "packed load must not quantize");
        std::hint::black_box(&m);
    });
    let speedup = s_f32 / s_pk;
    println!(
        "{:<44} {:>12.3} ms vs {:>10.3} ms   ({speedup:.2}x faster)",
        "cold start: load+pack vs load_packed",
        s_f32 * 1e3,
        s_pk * 1e3
    );

    rep.gate_min("mxpk_size_ratio", ratio, 3.0);
    rep.gate_min("packed_load_speedup", speedup, 5.0);

    let _ = std::fs::remove_dir_all(&dir);
    rep.finish_and_assert();
}
