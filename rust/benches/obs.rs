//! Bench: observability overhead gate — the tracing spans left
//! permanently in the packed-GEMM and decode hot paths must cost ≤ 3%
//! with tracing *enabled*, and one relaxed atomic load when disabled.
//!
//! Span granularity is deliberately coarse (one guard per GEMM call /
//! decode step, never per block or per row), so the enabled cost is a
//! few `Instant::now` calls against milliseconds of compute. This bench
//! pins that claim; `tests/obs.rs` pins the bitwise half (tracing never
//! moves a result bit). The ≤3% ratios and the nanosecond disabled-span
//! cost are data-driven gates in `BENCH_<gitrev>.json`.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;

use mxfp4_train::gemm::{mx_gemm_packed, Mat};
use mxfp4_train::model::{GPTConfig, NativeRecipe};
use mxfp4_train::obs::trace;
use mxfp4_train::rng::Rng;
use mxfp4_train::runtime::executor;
use mxfp4_train::serve::ServeModel;

const SEQ: usize = 128;

fn prompt(n: usize, vocab: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::seed(seed);
    (0..n).map(|_| (rng.next_u64() % vocab as u64) as i32).collect()
}

/// Seconds for 32 decode steps at window-edge depth (cloned state per
/// iteration, same shape as the decode bench's hot loop). Side
/// measurement: not recorded (the on/off *ratio* is what's gated).
fn decode_secs(model: &Arc<ServeModel>) -> f64 {
    let toks = prompt(SEQ - 33, model.vocab(), 2);
    let (state, _) = model.prefill(&toks).unwrap();
    harness::time_secs(1, 4, || {
        let mut st = state.clone();
        for i in 0..32 {
            std::hint::black_box(model.decode_step(&mut st, (i % 251) as i32).unwrap());
        }
    })
}

fn main() {
    assert!(!trace::enabled(), "bench must start with tracing off");
    let mut rep = harness::Reporter::start("obs");

    // -----------------------------------------------------------------
    // disabled-path cost: the permanent price of a span call site
    // -----------------------------------------------------------------
    rep.section("obs: disabled span call cost (the permanent hot-path tax)");
    const CALLS: usize = 1_000_000;
    let secs = rep.bench("disabled_span_call_x1m", CALLS as f64, "call", 1, 4, || {
        for _ in 0..CALLS {
            std::hint::black_box(trace::span("bench.noop"));
        }
    });
    let ns = secs / CALLS as f64 * 1e9;
    println!("disabled span construct+drop: {ns:.2} ns/call");
    rep.gate_max("disabled_span_ns", ns, 1000.0);

    // -----------------------------------------------------------------
    // 1024^3 packed GEMM: tracing off vs on (one span per GEMM call)
    // -----------------------------------------------------------------
    rep.section("obs: packed GEMM 1024^3, tracing off vs on (1 worker)");
    let mut rng = Rng::seed(0);
    let (m, n, k) = (1024usize, 1024usize, 1024usize);
    let aw = Mat::gaussian(m, k, 1.0, &mut rng);
    let bw = Mat::gaussian(n, k, 1.0, &mut rng); // Bᵀ-shaped
    let pa = aw.pack_nr();
    let pbt = bw.pack_nr();
    let flops = 2.0 * (m * n * k) as f64;

    let t_off = rep.bench("gemm_tracing_off", flops, "flop", 1, 2, || {
        std::hint::black_box(mx_gemm_packed(&pa, &pbt, 1));
    });
    trace::set_enabled(true);
    let t_on = rep.bench("gemm_tracing_on", flops, "flop", 1, 2, || {
        std::hint::black_box(mx_gemm_packed(&pa, &pbt, 1));
    });
    trace::set_enabled(false);
    trace::clear();
    let gemm_ratio = t_on / t_off;
    println!("gemm traced/untraced: {gemm_ratio:.4} (gate <= 1.03)");

    // -----------------------------------------------------------------
    // serving decode: tracing off vs on (spans per decode + per GEMM)
    // -----------------------------------------------------------------
    rep.section("obs: KV decode 2L d128, tracing off vs on (1 thread)");
    let cfg = GPTConfig::new(256, 128, 2, 4, SEQ, 0);
    let params = executor::init_params_for(&cfg.param_specs(), cfg.n_layers, 1);
    let model = Arc::new({
        let mut m = ServeModel::new(cfg, NativeRecipe::parse("mxfp4").unwrap(), params).unwrap();
        m.set_workers(1);
        m
    });
    let d_off = decode_secs(&model);
    trace::set_enabled(true);
    let d_on = decode_secs(&model);
    trace::set_enabled(false);
    trace::clear();
    let decode_ratio = d_on / d_off;
    println!(
        "decode untraced {:.3} us/tok, traced {:.3} us/tok, ratio {decode_ratio:.4} (gate <= 1.03)",
        d_off / 32.0 * 1e6,
        d_on / 32.0 * 1e6
    );

    rep.gate_max("gemm_tracing_ratio", gemm_ratio, 1.03);
    rep.gate_max("decode_tracing_ratio", decode_ratio, 1.03);

    rep.finish_and_assert();
}
