//! Bench: Table 5 regeneration (DESIGN.md T5) — the roofline-modeled
//! Llama-2-70B decoder-layer throughput per backward-precision config,
//! with the paper's qualitative checks asserted:
//!   INT4 > INT8 > FP16; RHT overhead < 5% E2E for g <= 256; the
//!   O(n log n) kernel recovers most of the dense g=1024 penalty; and
//!   the §1 headline backward speedups (>1.3x vs 8-bit, >1.7x vs 16-bit).
//! The roofline checks are deterministic, so they run as data-driven
//! gates in `BENCH_<gitrev>.json` (one set per modeled accelerator).

#[path = "harness.rs"]
mod harness;

use mxfp4_train::gemm::{mx_gemm_packed, mx_matmul, Mat, MxMode};
use mxfp4_train::mx::pipeline::PackPipeline;
use mxfp4_train::perfmodel::{self, BwConfig, RhtStyle, LLAMA2_70B_LAYER};
use mxfp4_train::rng::Rng;

fn main() {
    let mut rep = harness::Reporter::start("throughput");
    for hw in [perfmodel::A100, perfmodel::B200] {
        let tag = hw.name.to_lowercase();
        rep.section(&format!("Table 5 (modeled, {}): Llama-2-70B decoder layer", hw.name));
        println!("{:<28} {:>12} {:>12}", "BW pass", "E2E tok/s", "BW tok/s");
        let mut rows = Vec::new();
        for cfg in perfmodel::table5_configs() {
            let row = perfmodel::table5_row(&hw, &LLAMA2_70B_LAYER, &cfg);
            println!("{:<28} {:>12.0} {:>12.0}", row.0, row.1, row.2);
            rows.push(row);
        }
        let get = |label: &str| rows.iter().find(|r| r.0 == label).unwrap().1;

        rep.gate_min(&format!("{tag}_int4_over_int8"), get("INT4 no RHT") / get("INT8 no RHT"), 1.0);
        rep.gate_min(&format!("{tag}_int8_over_fp16"), get("INT8 no RHT") / get("FP16"), 1.0);
        let rht_overhead = 1.0 - get("INT4 + RHT g=256") / get("INT4 no RHT");
        rep.gate_max(&format!("{tag}_rht_e2e_overhead"), rht_overhead, 0.06);
        rep.gate_min(
            &format!("{tag}_nlogn_over_dense_g1024"),
            get("INT4 + RHT g=1024 nlogn") / get("INT4 + RHT g=1024 dense"),
            1.0,
        );

        let (vs8, vs16) = perfmodel::headline_speedups(&hw, &LLAMA2_70B_LAYER);
        println!("headline backward speedup: {vs8:.2}x vs 8-bit, {vs16:.2}x vs 16-bit");
        rep.gate_min(&format!("{tag}_headline_vs_8bit"), vs8, 1.3);
        rep.gate_min(&format!("{tag}_headline_vs_16bit"), vs16, 1.7);
    }

    rep.section("paper Table 5 (measured by the authors, for reference)");
    println!("FP16 bw 94688 tok/s | INT8 133952* | INT4 208662* | INT4+RHT g=64 197139*");
    println!("(*paper numbers are HuggingFace-stack measurements: 94688/123056/133952;");
    println!(" our roofline is the idealized ceiling — ordering and ratios match)");

    // Measured counterpart on the rust substrate: the roofline above is
    // the HW ceiling; here we time the two emulation paths and report the
    // operand bytes each one streams. The packed engine touches 8x fewer
    // operand bytes (4.25 vs 32 bits/elem) and pays quantization once —
    // the software shape of Table 5's bandwidth argument.
    rep.section("measured rust substrate (512x1024x512 GEMM, NR)");
    let mut rng = Rng::seed(4);
    let a = Mat::gaussian(512, 1024, 1.0, &mut rng);
    let b = Mat::gaussian(1024, 512, 1.0, &mut rng);
    let flops = 2.0 * 512.0 * 1024.0 * 512.0;
    let t_qdq = rep.bench("qdq_mx_matmul", flops, "flop", 0, 2, || {
        std::hint::black_box(mx_matmul(&a, &b, MxMode::Nr, 64, &mut Rng::seed(1), 4));
    });
    let pa = a.pack_nr();
    let pbt = PackPipeline::transposed(&b.data, 512, 1024).pack_nr(4);
    let t_packed = rep.bench("packed_gemm_prepacked", flops, "flop", 0, 2, || {
        std::hint::black_box(mx_gemm_packed(&pa, &pbt, 4));
    });
    let f32_bytes = (a.data.len() + b.data.len()) * 4;
    let mx_bytes = pa.packed_bytes() + pbt.packed_bytes();
    println!(
        "operand bytes: f32 {f32_bytes} vs packed {mx_bytes} ({:.2}x smaller); \
         packed/qdq wall-time ratio {:.2}",
        f32_bytes as f64 / mx_bytes as f64,
        t_qdq / t_packed
    );

    // sensitivity: the crossover where dense RHT stops being memory-bound
    rep.section("RHT memory-bound crossover (modeled)");
    for g in [64usize, 128, 256, 512, 1024] {
        let t = perfmodel::bw_time_per_token(
            &perfmodel::A100,
            &LLAMA2_70B_LAYER,
            &BwConfig { label: "", speed_mult: 4.0, rht: RhtStyle::Dense { g }, stochastic: true },
        );
        let t0 = perfmodel::bw_time_per_token(
            &perfmodel::A100,
            &LLAMA2_70B_LAYER,
            &BwConfig { label: "", speed_mult: 4.0, rht: RhtStyle::None, stochastic: true },
        );
        println!("g = {g:>5}: RHT adds {:>6.2}% to the backward pass", 100.0 * (t - t0) / t0);
    }

    rep.finish_and_assert();
}
