#!/usr/bin/env bash
# Bench orchestrator (documented in docs/OBSERVABILITY.md).
#
# Builds the release binary, runs the selected in-process benchmark
# suites through `mxfp4-train bench`, and compares the emitted
# BENCH_<gitrev>.json against the committed BENCH_baseline.json with the
# noise-aware rule (regression iff the median worsens by more than
# max(5%, 3x MAD)). Exits nonzero on any failed gate or regression.
#
# Usage: ./scripts/bench.sh [--suite micro|full] [--suites a,b,c]
#                           [--out path] [--update-baseline] [--no-compare]
#                           [--selftest]
#
#   --suite micro      shrunken shapes, seconds per suite (default; what
#                      CI runs) — perf gates are recorded but sized-down
#   --suite full       bench-target shapes with the canonical gates
#   --suites a,b,c     subset of: gemm pack quant decode ckpt obs
#   --out <path>       report destination (default: repo root,
#                      BENCH_<gitrev>.json)
#   --update-baseline  copy the fresh report over BENCH_baseline.json
#   --no-compare       skip the baseline comparison
#   --selftest         CI mode: run the micro suites to a scratch
#                      report, validate its schema, prove the comparator
#                      passes an unchanged rerun AND flags an injected
#                      2x slowdown, then clean up. No baseline needed.

set -euo pipefail

cd "$(dirname "$0")/.."
BIN=rust/target/release/mxfp4-train

SELFTEST=0
ARGS=()
for a in "$@"; do
    case "$a" in
        --selftest) SELFTEST=1 ;;
        *) ARGS+=("$a") ;;
    esac
done

echo "==> cargo build --release"
(cd rust && cargo build --release)

if [[ "$SELFTEST" == "1" ]]; then
    scratch="$(mktemp -d)"
    trap 'rm -rf "$scratch"' EXIT
    report="$scratch/BENCH_selftest.json"

    echo "==> bench selftest: micro suites -> $report"
    "$BIN" bench --suite micro --out "$report" --no-compare

    echo "==> bench selftest: schema validation"
    "$BIN" bench --validate "$report"

    echo "==> bench selftest: comparator must pass an unchanged rerun"
    "$BIN" bench --compare-only --baseline "$report" --report "$report"

    echo "==> bench selftest: comparator must flag an injected 2x slowdown"
    if "$BIN" bench --compare-only --baseline "$report" --report "$report" \
        --inject-slowdown 2 >"$scratch/inject.log" 2>&1; then
        echo "FAIL: comparator accepted a synthetic 2x regression"
        cat "$scratch/inject.log"
        exit 1
    fi
    grep -q "REGRESSED" "$scratch/inject.log" \
        || { echo "FAIL: no REGRESSED verdict in the injected-slowdown table"; cat "$scratch/inject.log"; exit 1; }
    echo "    (regression correctly flagged, nonzero exit)"
    echo "==> bench selftest passed"
    exit 0
fi

exec "$BIN" bench "${ARGS[@]}"
