#!/usr/bin/env bash
# Pre-merge gate for mxfp4-train (documented in README.md).
#
# Runs, in order:
#   1. cargo fmt --check   (formatting)
#   2. cargo build --release
#   3. cargo test -q       (tier-1: unit + property + gated integration)
#   3b. SIMD/scalar kernel parity suites by name, under both the
#      auto-detected dispatch and MX_FORCE_SCALAR=1 (gemm::simd contract)
#   4. compile-check every bench and example target
#   5. quickstart on the native backend: a real 20-step train whose loss
#      must decrease (the example exits nonzero otherwise)
#   6. serve smoke: a 16-token native KV-cached decode that must echo a
#      completion and exit 0
#   6b. observability: the obs_ contract suite with tracing off AND
#      MXFP4_TRACE=1, plus a --metrics-dump/--trace-out smoke whose
#      JSON snapshot must report the tokens actually served
#   6c. packed checkpoints: the store/golden format contracts (buffered
#      and --features mmap readers), a train -> convert -> serve smoke
#      asserting byte-identical completions + zero quantize packs, and
#      the benches/ckpt.rs size/cold-start gates
#   6d. bench reports: scripts/bench.sh --selftest (micro suites emit a
#      schema-valid BENCH_<gitrev>.json; the noise-aware comparator
#      passes an unchanged rerun and flags an injected 2x slowdown)
#   7. cargo doc           (rustdoc, warnings denied)
#
# Usage: ./scripts/ci.sh        (from the repo root; any extra args are
#        passed through to `cargo test`)

set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "==> cargo fmt --check"
# fmt requires the rustfmt component; skip with a notice if absent so the
# gate still runs on minimal toolchains.
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "    (rustfmt unavailable; skipping format check)"
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q "$@"

echo "==> fused-pipeline parity tests (PackPipeline vs materialized prep reference)"
# run the parity matrix by name (the `fused_` prefix selects: pack-level
# parity across modes x orientations x odd shapes, GEMM-level parity for
# all 5 modes, and the SR dither-stream / worker-count contracts) so a
# filtered "$@" above can never silently skip it
cargo test -q --test packed_gemm fused_

echo "==> SIMD/scalar kernel parity (auto-detected dispatch)"
# run the differential suite by name (simd_ selects the row_dot unit
# parity, the shape x mode x worker fuzz sweep, the dispatch-env seam,
# and the entry-level parity check; prop_kernel_ selects the E8M0
# extreme / all-zero / sign-flip / finiteness edge properties) so a
# filtered "$@" above can never silently skip it
cargo test -q --test packed_gemm simd_
cargo test -q --test properties prop_kernel_

echo "==> SIMD/scalar kernel parity (MX_FORCE_SCALAR=1 dispatch)"
# same suite with the env override live: proves the forced-scalar path
# dispatches AND that every in-process comparison still holds when the
# ambient kernel is the scalar oracle itself
MX_FORCE_SCALAR=1 cargo test -q --test packed_gemm simd_
MX_FORCE_SCALAR=1 cargo test -q --test properties prop_kernel_

echo "==> compile benches + examples"
# covers every [[bench]] target, including the new `pack` bench
# (fused-vs-materialized prep + the counting-allocator assert)
cargo build --release --benches --examples

echo "==> quickstart (native-capable 20-step train, loss must decrease)"
cargo run --release --example quickstart

echo "==> serve smoke (16-token native KV-cached decode, test config)"
# must echo a completion (a JSON response line with generated tokens)
# and the tokens/sec summary, and exit 0. test config (seq 32) leaves
# window room for all 16 tokens — the engine retires at the context
# window instead of sliding (see docs/SERVING.md).
serve_out=$(cargo run --release -- serve --backend native --config test \
    --recipe mxfp4 --prompt 1,2,3,4 --tokens 16)
echo "$serve_out"
echo "$serve_out" | grep -q '"tokens":' || {
    echo "serve smoke: no completion echoed" >&2
    exit 1
}
echo "$serve_out" | grep -q 'tok/s' || {
    echo "serve smoke: no throughput summary" >&2
    exit 1
}

echo "==> speculative-decode smoke (draft == target must reproduce the vanilla stream)"
# same request as the serve smoke, but drafted by the served model
# itself: the completion line must be byte-identical to the non-spec
# run (exact acceptance), and the acceptance summary must report on it
spec_out=$(cargo run --release -- serve --backend native --config test \
    --recipe mxfp4 --prompt 1,2,3,4 --tokens 16 --spec-draft target --spec-k 4)
echo "$spec_out"
base_line=$(echo "$serve_out" | grep '"tokens":')
spec_line=$(echo "$spec_out" | grep '"tokens":')
if [ "$spec_line" != "$base_line" ]; then
    echo "spec smoke: speculative completion diverged from vanilla decode" >&2
    echo "  vanilla: $base_line" >&2
    echo "  spec:    $spec_line" >&2
    exit 1
fi
echo "$spec_out" | grep -q 'speculative: .* accepted' || {
    echo "spec smoke: no acceptance-rate summary" >&2
    exit 1
}

echo "==> KV-rollback + speculative-decode + TCP contract tests (by name)"
# run the tests/spec.rs suites by prefix so a filtered "cargo test \$@"
# above can never silently skip them: rollback_ (truncate + re-decode
# bitwise == fresh prefill), spec_ (spec stream == vanilla stream,
# acceptance accounting), net_ (TCP front-end round trip)
cargo test -q --test spec rollback_
cargo test -q --test spec spec_
cargo test -q --test spec net_

echo "==> paged-KV contract tests (by name)"
# tests/paged_kv.rs by prefix: paged-vs-dense bitwise parity per recipe,
# truncate rollback on/straddling page boundaries, pool exhaustion ->
# queueing -> admission, evict/re-prefill byte identity, scratch reuse
cargo test -q --test paged_kv paged_

echo "==> observability contract tests (tracing off, then MXFP4_TRACE=1)"
# tests/obs.rs by prefix, twice: every assertion (bitwise parity,
# snapshot coverage, Chrome-trace export, TCP metrics command,
# EngineStats accounting) must hold with tracing disabled AND with the
# env switch enabling it at startup — instrumentation is read-only.
cargo test -q --test obs obs_
MXFP4_TRACE=1 cargo test -q --test obs obs_

echo "==> metrics-dump smoke (serve writes one JSON snapshot covering the run)"
# the dump must parse as JSON and report the generated tokens the smoke
# actually served (the bench gate for tracing overhead is benches/obs.rs,
# compile-checked above with the other bench targets)
dump=$(mktemp /tmp/mxfp4-metrics.XXXXXX.json)
trace=$(mktemp /tmp/mxfp4-trace.XXXXXX.json)
cargo run --release -- serve --backend native --config test \
    --recipe mxfp4 --prompt 1,2,3,4 --tokens 8 \
    --metrics-dump "$dump" --trace-out "$trace" >/dev/null
if command -v python3 >/dev/null 2>&1; then
    python3 - "$dump" "$trace" <<'EOF'
import json, sys
snap = json.load(open(sys.argv[1]))
gen = snap["gauges"]["engine.generated_tokens"]
assert gen > 0, f"metrics dump reports no generated tokens: {gen}"
trace = json.load(open(sys.argv[2]))
assert trace["traceEvents"], "trace-out exported no spans"
print(f"metrics dump ok: {gen:.0f} tokens, {len(trace['traceEvents'])} spans")
EOF
else
    grep -q '"engine.generated_tokens"' "$dump" || {
        echo "metrics dump missing engine.generated_tokens" >&2
        exit 1
    }
    grep -q '"traceEvents":\[{' "$trace" || {
        echo "trace-out exported no spans" >&2
        exit 1
    }
fi
rm -f "$dump" "$trace"

echo "==> loadgen smoke (paged engine under concurrent TCP load, bounded KV)"
# small-scale run of the 1000-session load generator: 32 pipelined
# requests against a 24-page pool force queueing + eviction; the example
# asserts every request answers, no page overflows, and no page leaks.
# timeout turns an admission deadlock into a hard failure, not a hang.
timeout 300 cargo run --release --example loadgen -- \
    --conns 8 --per-conn 4 --pool-pages 24 --page-rows 4 --config micro --tokens 4
echo "==> loadgen full scale is: cargo run --release --example loadgen (1000 sessions)"

echo "==> packed-checkpoint contract tests (by name)"
# tests/store.rs (roundtrip, determinism, zero-quantize load parity,
# corruption paths) plus the self-contained byte-layout goldens in
# tests/golden.rs — run by name so a filtered "\$@" above can never
# silently skip the on-disk format contract
cargo test -q --test store
cargo test -q --test golden mxmat_byte_layout
cargo test -q --test golden mxpk_header

echo "==> mmap feature (mapped reader must pass the same store contract)"
cargo build --release --features mmap
cargo test -q --release --features mmap --test store

echo "==> packed-checkpoint smoke (train -> convert -> serve, zero quantize packs)"
# train 20 steps emitting checkpoints, convert the f32 master, then
# serve from both formats: the trainer-emitted and converted .mxpk must
# be byte-identical, the two 16-token completions must match exactly,
# and the packed serve must report zero quantize packs at load
ckroot=$(mktemp -d /tmp/mxfp4-ckpt.XXXXXX)
cargo run --release -- train --backend native --config test --recipe mxfp4 \
    --steps 20 --eval-every 0 --checkpoint-dir "$ckroot" >/dev/null
master=$(find "$ckroot" -name master.mxck | head -n1)
ckdir=$(dirname "$master")
[ -f "$ckdir/packed.mxpk" ] || {
    echo "ckpt smoke: trainer did not emit packed.mxpk" >&2
    exit 1
}
cargo run --release -- convert --checkpoint "$master" --config test --recipe mxfp4 \
    --out "$ckdir/converted.mxpk"
cmp -s "$ckdir/packed.mxpk" "$ckdir/converted.mxpk" || {
    echo "ckpt smoke: convert output differs from trainer-emitted packed.mxpk" >&2
    exit 1
}
mxck_out=$(cargo run --release -- serve --backend native --config test --recipe mxfp4 \
    --checkpoint "$master" --prompt 1,2,3,4 --tokens 16)
mxpk_out=$(cargo run --release -- serve --backend native \
    --checkpoint "$ckdir/packed.mxpk" --prompt 1,2,3,4 --tokens 16)
mxck_line=$(echo "$mxck_out" | grep '"tokens":')
mxpk_line=$(echo "$mxpk_out" | grep '"tokens":')
if [ -z "$mxck_line" ] || [ "$mxck_line" != "$mxpk_line" ]; then
    echo "ckpt smoke: .mxpk completion diverged from .mxck completion" >&2
    echo "  .mxck: $mxck_line" >&2
    echo "  .mxpk: $mxpk_line" >&2
    exit 1
fi
echo "$mxpk_out" | grep -q '0 quantize packs' || {
    echo "ckpt smoke: packed serve performed quantize work at startup" >&2
    exit 1
}
echo "$mxpk_out" | grep -q 'packed .mxpk' || {
    echo "ckpt smoke: serve did not auto-detect the .mxpk format" >&2
    exit 1
}
rm -rf "$ckroot"

echo "==> checkpoint bench gates (.mxpk >=3x smaller, packed load >=5x faster)"
cargo bench --bench ckpt

echo "==> bench report smoke (micro suites + schema validation + comparator both ways)"
# scripts/bench.sh --selftest: runs the micro suites to a scratch
# BENCH report, validates it against the schema, proves the comparator
# passes an unchanged rerun, and proves an injected synthetic 2x
# slowdown exits nonzero with a REGRESSED verdict.
(cd .. && ./scripts/bench.sh --selftest)

echo "==> cargo doc (deny warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "CI gate passed."
