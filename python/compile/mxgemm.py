"""Emulated MXFP4 GEMM with selectable implementation (L1 dispatch).

``mx_matmul`` is the single entry point the model's backward pass uses.
``impl="pallas"`` routes the RHT + quantize steps through the Pallas
kernels (fused prologue when both are on); ``impl="ref"`` uses the
pure-jnp oracle. Both are bit-identical (tests assert it) — the pallas
path is the deployable kernel structure, the ref path lowers to leaner
HLO for the big training artifacts (see DESIGN.md §Perf, L2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import fused, mxfp4, ref, rht

IMPLS = ("ref", "pallas")


def _quantize_operands_pallas(a, bt, mode, g, key, dtype="fp4"):
    """qdq both operands along their (last-axis) reduction dim via Pallas."""
    use_rht = mode.startswith("rht")
    use_sr = mode.endswith("sr")
    if use_rht:
        ks, ka, kb = jax.random.split(key, 3)
        sign = jax.random.rademacher(ks, (g,), dtype=jnp.float32)
        if use_sr:
            ua = jax.random.uniform(ka, a.shape, dtype=jnp.float32)
            ub = jax.random.uniform(kb, bt.shape, dtype=jnp.float32)
            qa = fused.rht_qdq(a, sign, ua, stochastic=True, dtype=dtype)
            qb = fused.rht_qdq(bt, sign, ub, stochastic=True, dtype=dtype)
        else:
            qa = fused.rht_qdq(a, sign, stochastic=False, dtype=dtype)
            qb = fused.rht_qdq(bt, sign, stochastic=False, dtype=dtype)
    elif use_sr:
        ka, kb = jax.random.split(key)
        ua = jax.random.uniform(ka, a.shape, dtype=jnp.float32)
        ub = jax.random.uniform(kb, bt.shape, dtype=jnp.float32)
        qa = mxfp4.mxfp4_qdq_sr(a, ua, dtype=dtype)
        qb = mxfp4.mxfp4_qdq_sr(bt, ub, dtype=dtype)
    else:
        qa = mxfp4.mxfp4_qdq_nr(a, dtype=dtype)
        qb = mxfp4.mxfp4_qdq_nr(bt, dtype=dtype)
    return qa, qb


def mx_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    mode: str = "rht_sr",
    g: int = 64,
    key: jax.Array | None = None,
    impl: str = "pallas",
    dtype: str = "fp4",
) -> jnp.ndarray:
    """C = A @ B through the paper's emulated MXFP4 pipeline.

    A: (r, k), B: (k, c). See ``ref.mx_matmul`` for mode semantics. The
    pallas impl quantizes B via its transpose so both operands group along
    the shared reduction dim k, exactly like ``MXFP4_GEMM`` in Alg. 3.
    """
    assert impl in IMPLS, impl
    if mode == "exact":
        return a @ b
    if impl == "ref":
        return ref.mx_matmul(a, b, mode=mode, g=g, key=key, dtype=dtype)

    assert key is not None or mode == "nr", mode
    if key is None:
        key = jax.random.PRNGKey(0)  # nr is deterministic; key unused
    qa, qbt = _quantize_operands_pallas(a, b.T, mode, g, key, dtype)
    c = qa @ qbt.T
    if mode.endswith("sr"):
        c = c * (16.0 / 9.0)
    return c
