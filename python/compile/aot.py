"""AOT compiler: lower (config, recipe) train/eval/logits graphs to HLO text.

This is the single point where python runs — ``make artifacts`` invokes it
once; afterwards the rust coordinator is self-contained.

Interchange format is **HLO text**, NOT ``lowered.compile().serialize()``:
the image's xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction
ids); the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Lowering goes stablehlo -> XlaComputation ->
``as_hlo_text()`` exactly like the reference ``gen_hlo.py``.

Every ``<name>.hlo.txt`` ships a ``<name>.meta.json`` sidecar recording the
full input/output signature (names, shapes, dtypes) plus the parameter ABI
(the deterministic ``model.param_shapes`` order) — rust's artifact registry
parses these with its own JSON parser. A ``manifest.json`` indexes the set.

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts \
        [--configs test,tiny] [--recipes bf16,mxfp4_rht_sr,...] [--batch N]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, recipes

# Default batch size per named config (kept small: CPU-emulated MXFP4).
DEFAULT_BATCHES = {"test": 4, "tiny": 8, "small": 8, "base": 8}

# Default artifact matrix for `make artifacts`: every Table-2 recipe on the
# test + tiny configs (integration tests / quick sweeps), plus the headline
# recipe and baseline on small (the e2e example's model).
DEFAULT_PLAN = {
    "test": ["bf16", "mxfp4", "mxfp4_sr", "mxfp4_rht", "mxfp4_rht_sr"],
    "tiny": [
        "bf16",
        "mxfp4",
        "mxfp4_sr",
        "mxfp4_rht",
        "mxfp4_rht_sr",
        "mxfp4_rht_sr_g32",
        "mxfp4_rht_sr_g128",
        "mxint4_rht_sr",
        "fp8_fwd_mxfp4_rht_sr",
    ],
    "small": ["bf16", "mxfp4", "mxfp4_rht_sr"],
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path).

    ``print_large_constants=True`` is load-bearing: the default HLO printer
    elides tensors above ~1k elements as ``constant({...})`` and the 0.5.1
    text parser silently re-materializes them as ZEROS — corrupting the
    Hadamard matrix and the causal mask. (Found the hard way; see
    DESIGN.md §Gotchas.)
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _spec(name: str, shape, dtype: str) -> dict:
    return {"name": name, "shape": list(int(s) for s in shape), "dtype": dtype}


def _param_specs(cfg: model.GPTConfig) -> list[dict]:
    return [_spec(n, s, "f32") for n, s in model.param_shapes(cfg).items()]


def _abstract_args(cfg: model.GPTConfig, batch: int, kind: str):
    """ShapeDtypeStructs for the artifact signature, in ABI order."""
    tok = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    par = [jax.ShapeDtypeStruct(s, jnp.float32) for s in model.param_shapes(cfg).values()]
    if kind == "train":
        return [jax.ShapeDtypeStruct((), jnp.uint32), tok, tok, *par]
    if kind == "eval":
        return [tok, tok, *par]
    if kind == "logits":
        return [tok, *par]
    raise ValueError(kind)


def build_fn(cfg: model.GPTConfig, recipe: recipes.Recipe, kind: str):
    """A flat-argument wrapper around the model entry points."""
    names = list(model.param_shapes(cfg).keys())

    if kind == "train":

        def fn(seed, tokens, labels, *flat):
            params = dict(zip(names, flat))
            return model.train_step(params, tokens, labels, seed, cfg, recipe)

    elif kind == "eval":

        def fn(tokens, labels, *flat):
            params = dict(zip(names, flat))
            return model.eval_step(params, tokens, labels, cfg, recipe)

    elif kind == "logits":

        def fn(tokens, *flat):
            params = dict(zip(names, flat))
            return model.logits_fn(params, tokens, cfg, recipe)

    else:
        raise ValueError(kind)
    return fn


def artifact_meta(
    name: str, kind: str, cfg_name: str, cfg: model.GPTConfig, recipe: recipes.Recipe, batch: int
) -> dict:
    b, t, v = batch, cfg.seq_len, cfg.vocab
    params = _param_specs(cfg)
    if kind == "train":
        inputs = [
            _spec("seed", (), "u32"),
            _spec("tokens", (b, t), "i32"),
            _spec("labels", (b, t), "i32"),
            *params,
        ]
        outputs = [_spec("loss", (), "f32")] + [
            _spec(f"grad_{p['name']}", p["shape"], "f32") for p in params
        ]
    elif kind == "eval":
        inputs = [_spec("tokens", (b, t), "i32"), _spec("labels", (b, t), "i32"), *params]
        outputs = [_spec("loss", (), "f32")]
    else:  # logits
        inputs = [_spec("tokens", (b, t), "i32"), *params]
        outputs = [_spec("logits", (b, t, v), "f32")]
    return {
        "name": name,
        "kind": kind,
        "config_name": cfg_name,
        "config": dataclasses.asdict(cfg),
        "recipe": dataclasses.asdict(recipe),
        "recipe_name": recipe.name,
        "batch": batch,
        "param_count": cfg.param_count(),
        "inputs": inputs,
        "outputs": outputs,
        "params": params,
    }


def emit(out_dir: str, cfg_name: str, recipe_name: str, kind: str, batch: int) -> dict:
    cfg = model.CONFIGS[cfg_name]
    recipe = recipes.get(recipe_name)
    name = f"{cfg_name}_{recipe_name}_{kind}"
    fn = build_fn(cfg, recipe, kind)
    t0 = time.time()
    # keep_unused: the artifact ABI is positional — e.g. the `seed` input is
    # unused in the deterministic bf16/exact recipe but rust always feeds it.
    lowered = jax.jit(fn, keep_unused=True).lower(*_abstract_args(cfg, batch, kind))
    text = to_hlo_text(lowered)
    meta = artifact_meta(name, kind, cfg_name, cfg, recipe, batch)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    with open(os.path.join(out_dir, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"  {name}: {len(text)/1e6:.2f} MB HLO in {time.time()-t0:.1f}s")
    return {"name": name, "kind": kind, "config": cfg_name, "recipe": recipe_name, "batch": batch}


def emit_golden(out_dir: str) -> None:
    """Golden vectors: the cross-language bit-accuracy contract.

    The rust `mx`/`hadamard` substrates must reproduce these outputs
    *exactly* (cargo test `golden::`) — this pins rust to the same
    semantics pytest pins the Pallas kernels to.
    """
    from .kernels import ref

    key = jax.random.PRNGKey(1234)
    cases = []
    for i, scale in enumerate([1e-4, 0.37, 1.0, 42.0, 3e4]):
        k = jax.random.fold_in(key, i)
        v = jax.random.normal(k, (2, 64)) * scale
        q = ref.quantize_mx_nr(v)
        g = ref._group(v, ref.MX_BLOCK)
        x = ref.shared_scale(g)[..., 0]
        cases.append(
            {
                "input": [float(f) for f in v.flatten().tolist()],
                "shape": list(v.shape),
                "qdq_nr": [float(f) for f in q.flatten().tolist()],
                "scales": [float(f) for f in x.flatten().tolist()],
            }
        )
    # RHT with a fixed sign vector (deterministic given sign)
    sign = jnp.asarray([1.0, -1.0] * 32)  # g = 64
    v = jax.random.normal(jax.random.fold_in(key, 99), (4, 128)) * 2.0
    t = ref.rht_last_axis(v, sign)
    rht_case = {
        "sign": [float(f) for f in sign.tolist()],
        "input": [float(f) for f in v.flatten().tolist()],
        "shape": list(v.shape),
        "output": [float(f) for f in t.flatten().tolist()],
    }
    # SR with explicit dither noise (deterministic given u)
    vv = jax.random.normal(jax.random.fold_in(key, 7), (2, 32)) * 1.7
    u = jax.random.uniform(jax.random.fold_in(key, 8), (2, 32))
    qs = ref.quantize_mx_sr(vv, u)
    sr_case = {
        "input": [float(f) for f in vv.flatten().tolist()],
        "noise": [float(f) for f in u.flatten().tolist()],
        "shape": list(vv.shape),
        "qdq_sr": [float(f) for f in qs.flatten().tolist()],
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump({"quant_nr": cases, "rht": rht_case, "quant_sr": sr_case}, f)
    print("  golden.json (rust bit-accuracy vectors)")


def _write_mxck(path: str, names: list[str], tensors) -> None:
    """Write the rust checkpoint format (coordinator/checkpoint.rs)."""
    import struct

    with open(path, "wb") as f:
        f.write(b"MXCK")
        f.write(struct.pack("<I", 1))
        f.write(struct.pack("<I", len(names)))
        for name, t in zip(names, tensors):
            import numpy as np

            arr = np.asarray(t, dtype="<f4").reshape(-1)
            f.write(struct.pack("<I", len(name)))
            f.write(name.encode())
            f.write(struct.pack("<Q", arr.size))
            f.write(arr.tobytes())


def emit_model_golden(out_dir: str) -> None:
    """Model-level cross-language check: fixed params + batch -> the loss
    the `test_bf16_eval` artifact must reproduce when rust executes it."""
    import numpy as np

    cfg = model.CONFIGS["test"]
    recipe = recipes.get("bf16")
    params = model.init_params(jax.random.PRNGKey(42), cfg)
    names = list(model.param_shapes(cfg).keys())
    batch = DEFAULT_BATCHES["test"]
    tokens = jax.random.randint(jax.random.PRNGKey(1), (batch, cfg.seq_len), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(2), (batch, cfg.seq_len), 0, cfg.vocab)
    (loss,) = model.eval_step(params, tokens, labels, cfg, recipe)
    _write_mxck(os.path.join(out_dir, "golden_params.mxck"), names, [params[n] for n in names])
    doc = {
        "tokens": np.asarray(tokens).flatten().tolist(),
        "labels": np.asarray(labels).flatten().tolist(),
        "expected_loss": float(loss),
    }
    with open(os.path.join(out_dir, "golden_model.json"), "w") as f:
        json.dump(doc, f)
    print(f"  golden_model.json (expected eval loss {float(loss):.6f})")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default=None, help="comma list; default = plan")
    ap.add_argument("--recipes", default=None, help="comma list; default = plan per config")
    ap.add_argument("--batch", type=int, default=None, help="override batch size")
    ap.add_argument("--kinds", default="train,eval,logits")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    plan = dict(DEFAULT_PLAN)
    if args.configs:
        cfgs = args.configs.split(",")
        plan = {c: (args.recipes.split(",") if args.recipes else DEFAULT_PLAN.get(c, ["bf16"])) for c in cfgs}
    elif args.recipes:
        plan = {c: args.recipes.split(",") for c in plan}

    kinds = args.kinds.split(",")
    manifest = []
    t0 = time.time()
    for cfg_name, recipe_names in plan.items():
        batch = args.batch or DEFAULT_BATCHES[cfg_name]
        print(f"[{cfg_name}] batch={batch} recipes={recipe_names}")
        for rn in recipe_names:
            if "train" in kinds:
                manifest.append(emit(args.out_dir, cfg_name, rn, "train", batch))
        # eval + logits don't depend on the backward recipe — emit once per
        # distinct forward precision present in the recipe list.
        fwd_seen = set()
        for rn in recipe_names:
            fwd = recipes.get(rn).fwd
            if fwd in fwd_seen:
                continue
            fwd_seen.add(fwd)
            if "eval" in kinds:
                manifest.append(emit(args.out_dir, cfg_name, rn, "eval", batch))
            if "logits" in kinds:
                manifest.append(emit(args.out_dir, cfg_name, rn, "logits", batch))

    emit_golden(args.out_dir)
    emit_model_golden(args.out_dir)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest}, f, indent=1)
    print(f"wrote {len(manifest)} artifacts in {time.time()-t0:.1f}s -> {args.out_dir}")


if __name__ == "__main__":
    main()
