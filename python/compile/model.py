"""L2: GPT decoder with MXFP4 backward-pass linear layers.

A functional (pure-pytree) GPT-2-style decoder:

  * tied token embedding / LM head, learned positional embeddings,
  * pre-LN blocks: causal MHA + GELU MLP,
  * every *decoder linear layer* (qkv, attn-proj, fc1, fc2) is an
    ``MxLinear``: forward runs in the recipe's mixed precision
    (BF16 / FP8 qdq emulation), backward computes dL/dx and dL/dW through
    the emulated MXFP4 GEMM of Algorithm 3 (RHT -> quantize -> GEMM ->
    16/9 rescale), via ``jax.custom_vjp``.

Everything the rust coordinator executes is lowered from here by
``aot.py``: ``train_step`` (loss + grads), ``eval_step`` (loss only) and
``logits`` (for the downstream-eval harness). Layer parameters are
stacked on a leading axis and the blocks run under ``jax.lax.scan`` so
the lowered HLO stays compact at any depth.

Randomness (SR dither, RHT signs) derives from a ``seed`` *input* to the
artifact: rust feeds a fresh seed each step, keeping the compiled module
pure and the run bit-reproducible.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import mxgemm
from .kernels import ref
from .recipes import Recipe

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GPTConfig:
    """Architecture hyperparameters (mirrors the paper's appendix table)."""

    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    seq_len: int = 64
    d_ff: int = 0  # 0 -> 4 * d_model

    def __post_init__(self):
        object.__setattr__(self, "d_ff", self.d_ff or 4 * self.d_model)
        assert self.d_model % self.n_heads == 0
        assert self.d_model % 32 == 0, "MX groups must tile d_model"
        assert self.d_ff % 32 == 0, "MX groups must tile d_ff"

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        shapes = param_shapes(self)
        return int(sum(np.prod(s) for s in shapes.values()))


# Named model sizes used across examples/benches (DESIGN.md §6).
CONFIGS = {
    "test": GPTConfig(vocab=256, d_model=64, n_layers=2, n_heads=2, seq_len=32),
    "tiny": GPTConfig(vocab=256, d_model=128, n_layers=4, n_heads=4, seq_len=64),
    "small": GPTConfig(vocab=256, d_model=256, n_layers=6, n_heads=8, seq_len=128),
    "base": GPTConfig(vocab=256, d_model=512, n_layers=8, n_heads=8, seq_len=256),
}


# ---------------------------------------------------------------------------
# Parameters (flat dict, deterministic order — the rust ABI)
# ---------------------------------------------------------------------------


def param_shapes(cfg: GPTConfig) -> Dict[str, Tuple[int, ...]]:
    """Flat name -> shape map. Layer tensors are stacked on axis 0.

    The *iteration order of this dict* is the parameter ABI: aot.py records
    it in the artifact metadata and rust flattens its parameter store in
    the same order.
    """
    d, f, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    return {
        "tok_emb": (cfg.vocab, d),
        "pos_emb": (cfg.seq_len, d),
        "ln1_g": (L, d),
        "ln1_b": (L, d),
        "qkv_w": (L, 3 * d, d),
        "proj_w": (L, d, d),
        "ln2_g": (L, d),
        "ln2_b": (L, d),
        "fc1_w": (L, f, d),
        "fc2_w": (L, d, f),
        "lnf_g": (d,),
        "lnf_b": (d,),
    }


def init_params(key: jax.Array, cfg: GPTConfig) -> Params:
    """GPT-2 style init: N(0, 0.02), residual projections scaled by depth."""
    shapes = param_shapes(cfg)
    keys = jax.random.split(key, len(shapes))
    params: Params = {}
    resid_scale = 1.0 / np.sqrt(2 * cfg.n_layers)
    for (name, shape), k in zip(shapes.items(), keys):
        if name.endswith("_g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            w = jax.random.normal(k, shape, jnp.float32) * 0.02
            if name in ("proj_w", "fc2_w"):
                w = w * resid_scale
            params[name] = w
    return params


# ---------------------------------------------------------------------------
# MxLinear: the paper's contribution, as a custom_vjp
# ---------------------------------------------------------------------------


def _fwd_qdq(t: jnp.ndarray, fwd: str) -> jnp.ndarray:
    if fwd == "bf16":
        return ref.bf16_qdq(t)
    if fwd == "fp8":
        return ref.fp8_e4m3_qdq(t)
    return t  # f32


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def mx_linear(x: jnp.ndarray, w: jnp.ndarray, key: jax.Array, recipe: Recipe):
    """y = x @ w.T with recipe'd forward precision and MXFP4 backward.

    x: (..., n); w: (m, n); key drives the backward pass randomness (SR
    dither + RHT signs). Biases are omitted, as in the paper's GPT blocks
    (their dL/db is a cheap reduction anyway).
    """
    return _fwd_qdq(x, recipe.fwd) @ _fwd_qdq(w, recipe.fwd).T


def _mx_linear_fwd(x, w, key, recipe: Recipe):
    y = _fwd_qdq(x, recipe.fwd) @ _fwd_qdq(w, recipe.fwd).T
    return y, (x, w, key)


def _mx_linear_bwd(recipe: Recipe, res, gy):
    """Algorithm 3: both backward GEMMs through the emulated MXFP4 pipeline.

    dL/dx = G @ W     (reduction over m)
    dL/dW = G^T @ X   (reduction over the batch/token dim b)
    """
    x, w, key = res
    n = x.shape[-1]
    m = w.shape[0]
    x2 = x.reshape(-1, n)
    g2 = gy.reshape(-1, m)
    kx, kw = jax.random.split(key)
    dx = mxgemm.mx_matmul(
        g2, w, mode=recipe.bwd_mode, g=recipe.g, key=kx, impl=recipe.impl, dtype=recipe.dtype
    )
    dw = mxgemm.mx_matmul(
        g2.T, x2, mode=recipe.bwd_mode, g=recipe.g, key=kw, impl=recipe.impl, dtype=recipe.dtype
    )
    return dx.reshape(x.shape), dw, jnp.zeros_like(res[2])


mx_linear.defvjp(_mx_linear_fwd, _mx_linear_bwd)


# ---------------------------------------------------------------------------
# Transformer blocks
# ---------------------------------------------------------------------------


def layer_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def causal_attention(q, k, v, n_heads: int):
    """Standard causal MHA over (B, T, D) in f32 (attention itself is not a
    decoder *linear layer*; the paper leaves it in the forward precision)."""
    b, t, d = q.shape
    hd = d // n_heads

    def split(x):
        return x.reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    scores = (qh @ kh.transpose(0, 1, 3, 2)) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = probs @ vh
    return out.transpose(0, 2, 1, 3).reshape(b, t, d)


def block(x: jnp.ndarray, lp: Dict[str, jnp.ndarray], key: jax.Array, cfg: GPTConfig, recipe: Recipe):
    """One pre-LN decoder block; lp holds this layer's (unstacked) tensors."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    h = layer_norm(x, lp["ln1_g"], lp["ln1_b"])
    qkv = mx_linear(h, lp["qkv_w"], k1, recipe)
    q, k_, v = jnp.split(qkv, 3, axis=-1)
    attn = causal_attention(q, k_, v, cfg.n_heads)
    x = x + mx_linear(attn, lp["proj_w"], k2, recipe)
    h = layer_norm(x, lp["ln2_g"], lp["ln2_b"])
    h = mx_linear(h, lp["fc1_w"], k3, recipe)
    h = jax.nn.gelu(h)
    x = x + mx_linear(h, lp["fc2_w"], k4, recipe)
    return x


LAYER_PARAMS = ("ln1_g", "ln1_b", "qkv_w", "proj_w", "ln2_g", "ln2_b", "fc1_w", "fc2_w")


def forward(params: Params, tokens: jnp.ndarray, seed: jnp.ndarray, cfg: GPTConfig, recipe: Recipe):
    """Logits (B, T, V). ``seed`` is a scalar uint32 driving all randomness."""
    b, t = tokens.shape
    base = jax.random.key(seed)
    x = params["tok_emb"][tokens] + params["pos_emb"][:t][None, :, :]

    stacked = {n: params[n] for n in LAYER_PARAMS}
    layer_keys = jax.random.split(jax.random.fold_in(base, 1), cfg.n_layers)

    def body(h, xs):
        lp, k = xs
        return block(h, lp, k, cfg, recipe), None

    x, _ = jax.lax.scan(body, x, (stacked, layer_keys))
    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    # tied LM head — also an MxLinear (it is a decoder linear layer)
    head_key = jax.random.fold_in(base, 2)
    logits = mx_linear(x, params["tok_emb"], head_key, recipe)
    return logits


def loss_fn(params: Params, tokens, labels, seed, cfg: GPTConfig, recipe: Recipe):
    """Mean autoregressive cross-entropy; labels = tokens shifted by one."""
    logits = forward(params, tokens, seed, cfg, recipe)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


# ---------------------------------------------------------------------------
# AOT entry points (what rust executes)
# ---------------------------------------------------------------------------


def train_step(params: Params, tokens, labels, seed, cfg: GPTConfig, recipe: Recipe):
    """(loss, grads) — grads in param_shapes order, one per parameter."""
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels, seed, cfg, recipe)
    return (loss, *[grads[n] for n in param_shapes(cfg)])


def eval_step(params: Params, tokens, labels, cfg: GPTConfig, recipe: Recipe):
    """Validation loss under the *forward* recipe (no backward noise)."""
    return (loss_fn(params, tokens, labels, jnp.uint32(0), cfg, recipe),)


def logits_fn(params: Params, tokens, cfg: GPTConfig, recipe: Recipe):
    """Raw logits for the downstream zero-shot / generation harness."""
    return (forward(params, tokens, jnp.uint32(0), cfg, recipe),)
