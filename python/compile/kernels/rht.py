"""Pallas kernel for the blockwise random Hadamard transform (§3.2).

The paper applies the RHT as a *dense* (g x g) matmul over g-element tiles
of the reduction dimension (g <= 256), arguing it stays memory-bound in
the GEMM operands. On TPU this maps directly onto the MXU: the precomputed
operator M = diag(S) @ H_g is a single (g, g) systolic tile that stays
resident in VMEM across the whole grid (its BlockSpec index map is
constant), while (BLK_R, g) operand tiles stream through HBM->VMEM once —
the same IO schedule as the paper's fused CUDA prologue.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .mxfp4 import pick_block

# (BLK_R, g) operand tiles: 2048 x 64 f32 = 512 KB per tile; the resident
# (g, g) operator adds at most 256 KB — comfortably inside VMEM while
# keeping the interpret-mode grid short (§Perf L1).
DEFAULT_BLK_R = 2048


def _rht_kernel(x_ref, m_ref, o_ref):
    """One (BLK_R, g) tile times the resident (g, g) RHT operator."""
    o_ref[...] = jnp.dot(x_ref[...], m_ref[...], preferred_element_type=jnp.float32)


def rht_last_axis(x: jnp.ndarray, sign: jnp.ndarray, blk_r: int = DEFAULT_BLK_R) -> jnp.ndarray:
    """Blockwise RHT along the last axis via a Pallas grid.

    Equivalent to ``ref.rht_last_axis``: the last axis is chopped into
    g-chunks (g = len(sign)) and each chunk is multiplied by
    diag(S) @ H_g. The input is viewed as (N/g, g) rows, so *all* leading
    structure — batch, sequence, rows of W — is flattened exactly like
    Algorithm 3's ``.view(bm/g, g)``.
    """
    g = sign.shape[0]
    shape = x.shape
    assert shape[-1] % g == 0, (shape, g)
    m = ref.rht_matrix(sign)  # (g, g), computed in-graph from the sign input
    x2 = x.reshape(-1, g)
    rows = x2.shape[0]
    br = pick_block(rows, blk_r)
    out = pl.pallas_call(
        _rht_kernel,
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, g), lambda i: (i, 0)),
            pl.BlockSpec((g, g), lambda i: (0, 0)),  # resident operator
        ],
        out_specs=pl.BlockSpec((br, g), lambda i: (i, 0)),
        interpret=True,
    )(x2, m)
    return out.reshape(shape)
