"""Pure-jnp reference oracle for the MXFP4 training pipeline.

This module is the *numeric ground truth* for the whole repo:

  * the Pallas kernels (`mxfp4.py`, `rht.py`, `fused.py`) are tested
    against it with pytest + hypothesis,
  * the rust `mx` / `hadamard` substrates mirror it bit-for-bit and are
    cross-checked via golden vectors generated from here.

Semantics follow the paper exactly:

  * FP4 is E2M1 (1 sign, 2 exponent, 1 mantissa; bias 1). Representable
    magnitudes: {0, 0.5, 1, 1.5, 2, 3, 4, 6}.
  * Algorithm 1 ("reference" OCP MX quantization): per 32-element group,
    shared_exp = floor(log2(max|v|)) - emax_elem  (emax_elem = 2 for FP4),
    X = 2^shared_exp, elements nearest-rounded to FP4 after dividing by X.
    Values scaled into (6, 8] clip to 6 — the bias the paper identifies.
  * Algorithm 2 (unbiased): elements additionally scaled by 3/4 before
    stochastic rounding, making the MX block an unbiased estimate of
    (3/4)·v; a GEMM of two such blocks estimates (9/16)·(A·B), undone by a
    16/9 rescale of the accumulator (Lemma 3.1).
  * Blockwise RHT (§3.2): x.view(-1, g) @ diag(S)·H_g with a single shared
    g-dim sign vector S; H_g is the orthonormal (1/sqrt(g)-scaled) Sylvester
    Hadamard matrix, so (HS)^T(HS) = I and the transform cancels inside the
    GEMM.

Everything is f32 "qdq" (quantize-dequantize) emulation: containers stay
f32 but every value is exactly X * (an FP4 grid point), which the tests
assert. This matches how the paper trains (Microsoft microxcaling
emulation) and how rust's bit-accurate codec checks us.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# FP4 (E2M1) grid
# ---------------------------------------------------------------------------

# Non-negative representable magnitudes of FP4 E2M1, ascending.
FP4_GRID = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], dtype=np.float32)
# Midpoints between consecutive grid values (used for nearest rounding).
FP4_MIDPOINTS = (FP4_GRID[:-1] + FP4_GRID[1:]) / 2.0
# Grid values with an even mantissa bit (M=0): used for ties-to-even.
FP4_EVEN_MASK = np.array([True, False, True, False, True, False, True, False])

FP4_MAX = 6.0  # largest normal magnitude
FP4_EMAX = 2  # exponent of the largest normal (6 = 1.5 * 2^2)
MX_BLOCK = 32  # OCP MX group size
E8M0_MIN, E8M0_MAX = -127, 127  # representable E8M0 shared-exponent range
# f32 qdq emulation clamps the shared exponent to the *normal* f32 range:
# XLA CPU flushes subnormals to zero, so X = 2^-127 would silently become 0
# (and 0/0 = NaN). 2^-126 is the smallest FTZ-safe scale; the rust codec
# mirrors this clamp so both sides stay bit-identical.
SCALE_EMIN, SCALE_EMAX = -126, 127


def exact_pow2(e: jnp.ndarray) -> jnp.ndarray:
    """Exact 2^e for integer e in [-126, 127], via exponent-field bitcast.

    ``jnp.exp2`` on XLA CPU is computed through a polynomial and is *wrong
    in the last ulp for most integer exponents* (measured: 221/254 exact
    powers of two are off) — unacceptable for a scale that must divide out
    exactly. Building the float from its bit pattern is exact.
    """
    e = jnp.clip(e.astype(jnp.int32), SCALE_EMIN, SCALE_EMAX)
    return jax.lax.bitcast_convert_type((e + 127) << 23, jnp.float32)


def fp4_nearest(x: jnp.ndarray) -> jnp.ndarray:
    """Round to the nearest FP4 (E2M1) value, ties-to-even mantissa.

    Input is clipped to [-6, 6] first (overflow saturates, as in OCP MX
    Algorithm 1 — this is exactly the clipping bias Algorithm 2 removes).
    """
    x = jnp.clip(x, -FP4_MAX, FP4_MAX)
    mag = jnp.abs(x)
    mids = jnp.asarray(FP4_MIDPOINTS)
    # index of nearest grid point; side differs only exactly on midpoints
    idx_up = jnp.searchsorted(mids, mag, side="right")
    idx_dn = jnp.searchsorted(mids, mag, side="left")
    # where mag sits exactly on a midpoint, idx_dn < idx_up; pick the even one
    grid = jnp.asarray(FP4_GRID)
    even = jnp.asarray(FP4_EVEN_MASK)
    tie = idx_dn != idx_up
    pick_dn = tie & even[jnp.clip(idx_dn, 0, 7)]
    idx = jnp.where(pick_dn, idx_dn, idx_up)
    return jnp.sign(x) * grid[idx]


def fp4_stochastic(x: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Stochastically round to the FP4 grid.

    ``u`` is i.i.d. uniform on [0, 1) with the same shape as ``x``. For x
    between consecutive grid points f <= x <= c, rounds up with probability
    (x - f) / (c - f) — exactly unbiased (E[SR(x)] = x) for |x| <= 6.
    Inputs outside [-6, 6] saturate (callers must pre-scale; Algorithm 2's
    3/4 factor guarantees in-range inputs).

    This is the "dithering" formulation of Eq. (1) generalized to the
    non-uniform FP4 grid: comparing u against the fractional position is
    equivalent to adding uniform noise scaled by the local gap (c - f) and
    nearest-rounding.
    """
    x = jnp.clip(x, -FP4_MAX, FP4_MAX)
    mag = jnp.abs(x)
    grid = jnp.asarray(FP4_GRID)
    # f = floor on grid, c = ceil on grid
    idx_c = jnp.clip(jnp.searchsorted(grid, mag, side="left"), 0, 7)
    c = grid[idx_c]
    idx_f = jnp.where(c == mag, idx_c, jnp.maximum(idx_c - 1, 0))
    f = grid[idx_f]
    gap = c - f
    # fractional position in [0, 1); 0 when on-grid (gap == 0)
    p = jnp.where(gap > 0, (mag - f) / jnp.where(gap > 0, gap, 1.0), 0.0)
    rounded = jnp.where(u < p, c, f)
    return jnp.sign(x) * rounded


# ---------------------------------------------------------------------------
# Shared exponent (E8M0 scale)
# ---------------------------------------------------------------------------


def floor_log2(m: jnp.ndarray) -> jnp.ndarray:
    """Exact floor(log2(m)) for m > 0 via exponent extraction (frexp).

    float log2 of a power of two can land just below the integer under
    fused-math; frexp is exact: m = mant * 2^e with mant in [0.5, 1), so
    floor(log2(m)) = e - 1.
    """
    _, e = jnp.frexp(m)
    return e - 1


def shared_scale(v: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Per-MX-group scale X = 2^shared_exp (Alg. 1 lines 1-2), keepdims.

    ``v`` must already be grouped: ``axis`` indexes within an MX group of
    size 32 (or any size — the formula only uses the max). An all-zero
    group gets X = 2^-126 (the FTZ-safe scale floor, see SCALE_EMIN) so qdq
    maps it to exact zeros. The shared exponent is clamped to the
    FTZ-safe sub-range of E8M0.
    """
    m = jnp.max(jnp.abs(v), axis=axis, keepdims=True)
    e = jnp.where(m > 0, floor_log2(jnp.where(m > 0, m, 1.0)), 0) - FP4_EMAX
    e = jnp.where(m > 0, e, SCALE_EMIN)
    return exact_pow2(e)


def _group(v: jnp.ndarray, block: int) -> jnp.ndarray:
    """Reshape the last axis into (..., n/block, block) MX groups."""
    assert v.shape[-1] % block == 0, (v.shape, block)
    return v.reshape(*v.shape[:-1], v.shape[-1] // block, block)


def _ungroup(v: jnp.ndarray) -> jnp.ndarray:
    return v.reshape(*v.shape[:-2], v.shape[-2] * v.shape[-1])


# ---------------------------------------------------------------------------
# Algorithm 1 / Algorithm 2 (qdq emulation along the last axis)
# ---------------------------------------------------------------------------


def quantize_mx_nr(v: jnp.ndarray, block: int = MX_BLOCK) -> jnp.ndarray:
    """Algorithm 1: biased OCP MX quantization (nearest rounding), qdq.

    Values scaled into (6, 8] by the shared exponent clip to 6, which is
    the source of the bias quantified in §3.1 (~3% of entries for wide
    distributions).
    """
    g = _group(v, block)
    x = shared_scale(g)
    q = fp4_nearest(g / x)
    return _ungroup(q * x)


def quantize_mx_sr(
    v: jnp.ndarray, u: jnp.ndarray, block: int = MX_BLOCK, prescale: bool = True
) -> jnp.ndarray:
    """Algorithm 2: unbiased MX quantization (3/4 pre-scale + SR), qdq.

    Returns an unbiased estimate of (3/4)·v — callers undo the (3/4)^2
    factor on the GEMM accumulator (16/9), per Lemma 3.1. ``u`` is uniform
    [0,1) noise of v's shape. ``prescale=False`` gives an SR-without-scale
    ablation (biased in the (6, 8] clip region).
    """
    g = _group(v, block)
    un = _group(u, block)
    x = shared_scale(g)
    scaled = g / x
    if prescale:
        scaled = scaled * 0.75
    q = fp4_stochastic(scaled, un)
    return _ungroup(q * x)


# ---------------------------------------------------------------------------
# MXINT4 (the paper's "our analysis also applies to MXINT4" extension)
# ---------------------------------------------------------------------------

INT4_MIN, INT4_MAX = -8.0, 7.0
# After the Alg.1-style shared exponent, magnitudes land in [4, 8); the
# uniform INT4 grid has gap Δ = 1 everywhere (vs FP4's 0.5/1/2 ladder).


def int4_nearest(x: jnp.ndarray) -> jnp.ndarray:
    """Round to the nearest INT4 integer, ties-to-even, saturating."""
    return jnp.clip(jnp.round(x), INT4_MIN, INT4_MAX)


def int4_stochastic(x: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Stochastically round to the INT4 grid (uniform Δ = 1 dithering —
    exactly Eq. 1 of the paper)."""
    x = jnp.clip(x, INT4_MIN, INT4_MAX)
    f = jnp.floor(x)
    p = x - f
    return jnp.where(u < p, jnp.minimum(f + 1.0, INT4_MAX), f)


def quantize_mxint_nr(v: jnp.ndarray, block: int = MX_BLOCK) -> jnp.ndarray:
    """MXINT4 Algorithm 1: shared exponent + nearest rounding, qdq.

    Uses the same shared-exponent rule as MXFP4 (floor(log2 max) - 2), so
    scaled magnitudes are < 8: the positive edge (7, 8) clips to 7 — the
    INT4 analogue of the (6, 8] FP4 clip bias.
    """
    g = _group(v, block)
    x = shared_scale(g)
    q = int4_nearest(g / x)
    return _ungroup(q * x)


def quantize_mxint_sr(v: jnp.ndarray, u: jnp.ndarray, block: int = MX_BLOCK) -> jnp.ndarray:
    """MXINT4 Algorithm 2: 3/4 pre-scale + SR -> unbiased estimate of (3/4)v.

    3/4 * 8 = 6 < 7, so the pre-scale removes clipping on both edges
    (|scaled| < 6 <= 7 and > -8), mirroring Lemma 3.1.
    """
    g = _group(v, block)
    un = _group(u, block)
    x = shared_scale(g)
    q = int4_stochastic(g / x * 0.75, un)
    return _ungroup(q * x)


# ---------------------------------------------------------------------------
# Blockwise random Hadamard transform (§3.2)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def hadamard_matrix(g: int) -> np.ndarray:
    """Orthonormal Sylvester Hadamard matrix H_g (g a power of two)."""
    assert g & (g - 1) == 0 and g > 0, f"g={g} must be a power of two"
    h = np.array([[1.0]], dtype=np.float64)
    while h.shape[0] < g:
        h = np.block([[h, h], [h, -h]])
    return (h / np.sqrt(g)).astype(np.float32)


def rht_matrix(sign: jnp.ndarray) -> jnp.ndarray:
    """Precomputed RHT operator M = diag(S) @ H_g  (g = len(sign)).

    Applying x.view(-1, g) @ M is the paper's blockwise RHT; M is
    orthogonal so M @ M^T = I.
    """
    g = sign.shape[0]
    h = jnp.asarray(hadamard_matrix(g))
    return sign[:, None].astype(jnp.float32) * h


def rht_last_axis(v: jnp.ndarray, sign: jnp.ndarray) -> jnp.ndarray:
    """Blockwise RHT along the last axis: per g-chunk, (chunk * S) @ H."""
    g = sign.shape[0]
    m = rht_matrix(sign)
    grouped = _group(v, g)
    return _ungroup(grouped @ m)


# ---------------------------------------------------------------------------
# Emulated MXFP4 GEMM (Algorithm 3 core)
# ---------------------------------------------------------------------------

MX_MODES = ("exact", "nr", "sr", "rht", "rht_sr")


def mx_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    mode: str = "rht_sr",
    g: int = 64,
    key: jax.Array | None = None,
    block: int = MX_BLOCK,
    dtype: str = "fp4",
) -> jnp.ndarray:
    """Emulated MXFP4/MXINT4 GEMM  C = A @ B  with the paper's recipe.

    A: (r, k), B: (k, c); MX groups are formed along the reduction dim k
    for both operands. Modes:

      * ``"nr"``      — Algorithm 1 only (biased; the "pure MXFP4" ablation)
      * ``"sr"``      — Algorithm 2, no RHT (unbiased, high variance)
      * ``"rht"``     — RHT + Algorithm 1 (biased, low distortion)
      * ``"rht_sr"``  — RHT + Algorithm 2 (the paper's recipe)
      * ``"exact"``   — plain f32 matmul (BF16-recipe stand-in)

    ``key`` drives SR dither noise and the RHT sign vector; required for
    any mode involving randomness.
    """
    assert mode in MX_MODES, mode
    if mode == "exact":
        return a @ b

    k = a.shape[-1]
    assert b.shape[0] == k
    use_rht = mode.startswith("rht")
    use_sr = mode.endswith("sr")

    ka = kb = None
    if use_rht:
        assert key is not None, f"mode {mode} needs a PRNG key"
        assert k % g == 0, (k, g)
        ks, ka, kb = jax.random.split(key, 3)
        sign = jax.random.rademacher(ks, (g,), dtype=jnp.float32)
        a = rht_last_axis(a, sign)
        b = rht_last_axis(b.T, sign).T  # transform B along its reduction dim
    elif use_sr:
        assert key is not None, f"mode {mode} needs a PRNG key"
        ka, kb = jax.random.split(key)

    q_sr = quantize_mxint_sr if dtype == "int4" else quantize_mx_sr
    q_nr = quantize_mxint_nr if dtype == "int4" else quantize_mx_nr
    if use_sr:
        ua = jax.random.uniform(ka, a.shape, dtype=jnp.float32)
        ub = jax.random.uniform(kb, b.shape, dtype=jnp.float32)
        qa = q_sr(a, ua, block)
        qb = q_sr(b.T, ub.T, block).T
        return (qa @ qb) * (16.0 / 9.0)
    else:
        qa = q_nr(a, block)
        qb = q_nr(b.T, block).T
        return qa @ qb


# ---------------------------------------------------------------------------
# FP8 / BF16 qdq emulation (forward-pass recipes)
# ---------------------------------------------------------------------------


def fp8_e4m3_qdq(x: jnp.ndarray) -> jnp.ndarray:
    """Quantize-dequantize through FP8 E4M3 (per-tensor amax scaling).

    Used for the FP8-forward-pass experiments (appendix §6.1). The paper's
    TE recipe uses delayed per-tensor scaling; we fold it into a simple
    amax-based per-tensor scale which has the same relative-error profile
    (~0.3% for Gaussian inputs, matching the appendix's emulation note).
    """
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, 448.0 / amax, 1.0)
    y = x * scale
    f8 = y.astype(jnp.float8_e4m3fn).astype(jnp.float32)
    return f8 / scale


def bf16_qdq(x: jnp.ndarray) -> jnp.ndarray:
    """Quantize-dequantize through BF16 (the baseline mixed-precision path)."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)
