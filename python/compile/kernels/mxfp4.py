"""Pallas kernels for MXFP4 quantize-dequantize (Algorithms 1 & 2).

TPU-shaped rethink of the paper's CUDA kernels (DESIGN.md
§Hardware-Adaptation):

  * The CUDA version computes the 32-wide block max with a warp shuffle;
    here each grid step owns a ``(BLK_R, BLK_C)`` VMEM tile and computes
    all its group maxima with an in-register reshape
    ``(R, C) -> (R, C/32, 32)`` + lane reduction — VPU-friendly, no
    cross-tile communication because MX groups never straddle tiles
    (32 | BLK_C is asserted).
  * Rounding is a branch-free ``select`` chain over the 8-point E2M1 grid
    (what a TPU VPU actually executes) rather than a table lookup.
  * SR dither noise arrives as an *input tile* streamed with the same
    BlockSpec as the operand. On Trainium/Blackwell this is a hardware
    dither; AOT-wise the noise is produced by ``jax.random`` inside the
    same HLO module from a seed the rust coordinator feeds each step.

Kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); structure — BlockSpec tiling, VMEM footprint — is what we
optimize and document, numerics are bit-identical to ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default tile shape: multiples of the (8, 128) f32 VPU tile, sized so one
# operand tile is 1 MB in VMEM (512 x 512 f32). Fewer, fatter grid steps:
# on real TPU this amortizes the per-step DMA + loop overhead against ~16MB
# of VMEM (in/out/noise tiles = 3 MB); under interpret=True it amortizes the
# per-step interpreter cost, which profiling showed dominates (§Perf L1).
DEFAULT_BLK_R = 512
DEFAULT_BLK_C = 512


def pick_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (prefers powers of 2)."""
    best = 1
    d = 1
    while d <= min(n, target):
        if n % d == 0:
            best = d
        d *= 2
    # fall back to a linear scan for non-power-of-two shapes
    for d in range(best + 1, min(n, target) + 1):
        if n % d == 0:
            best = d
    return best


# ---------------------------------------------------------------------------
# In-kernel FP4 rounding primitives (branch-free select chains)
# ---------------------------------------------------------------------------


def _fp4_nearest_tile(x):
    """Nearest-round a tile to the FP4 grid, ties-to-even (see ref.py)."""
    mag = jnp.abs(x)
    # Ties: 0.25->0, 0.75->1, 1.25->1, 1.75->2, 2.5->2, 3.5->4, 5->4
    q = jnp.where(
        mag <= 0.25,
        0.0,
        jnp.where(
            mag < 0.75,
            0.5,
            jnp.where(
                mag <= 1.25,
                1.0,
                jnp.where(
                    mag < 1.75,
                    1.5,
                    jnp.where(
                        mag <= 2.5,
                        2.0,
                        jnp.where(mag < 3.5, 3.0, jnp.where(mag <= 5.0, 4.0, 6.0)),
                    ),
                ),
            ),
        ),
    )
    return jnp.sign(x) * q


def _fp4_floor_ceil_tile(mag):
    """(floor, ceil) of a magnitude tile onto the FP4 grid; mag in [0, 6]."""
    f = jnp.where(
        mag >= 6.0,
        6.0,
        jnp.where(
            mag >= 4.0,
            4.0,
            jnp.where(
                mag >= 3.0,
                3.0,
                jnp.where(
                    mag >= 2.0,
                    2.0,
                    jnp.where(
                        mag >= 1.5,
                        1.5,
                        jnp.where(mag >= 1.0, 1.0, jnp.where(mag >= 0.5, 0.5, 0.0)),
                    ),
                ),
            ),
        ),
    )
    c = jnp.where(
        mag > 4.0,
        6.0,
        jnp.where(
            mag > 3.0,
            4.0,
            jnp.where(
                mag > 2.0,
                3.0,
                jnp.where(
                    mag > 1.5,
                    2.0,
                    jnp.where(
                        mag > 1.0,
                        1.5,
                        jnp.where(mag > 0.5, 1.0, jnp.where(mag > 0.0, 0.5, 0.0)),
                    ),
                ),
            ),
        ),
    )
    return f, c


def _fp4_stochastic_tile(x, u):
    """Stochastically round a tile to the FP4 grid (dither ``u`` in [0,1))."""
    x = jnp.clip(x, -ref.FP4_MAX, ref.FP4_MAX)
    mag = jnp.abs(x)
    f, c = _fp4_floor_ceil_tile(mag)
    gap = c - f
    p = jnp.where(gap > 0, (mag - f) / jnp.where(gap > 0, gap, 1.0), 0.0)
    return jnp.sign(x) * jnp.where(u < p, c, f)


def _int4_nearest_tile(x):
    """Nearest-round a tile to the INT4 grid (ties-to-even via round)."""
    return jnp.clip(jnp.round(x), -8.0, 7.0)


def _int4_stochastic_tile(x, u):
    """Stochastically round a tile to INT4 (uniform dither, Eq. 1)."""
    x = jnp.clip(x, -8.0, 7.0)
    f = jnp.floor(x)
    p = x - f
    return jnp.where(u < p, jnp.minimum(f + 1.0, 7.0), f)


def _nearest_tile(x, dtype):
    if dtype == "int4":
        return _int4_nearest_tile(x)
    return _fp4_nearest_tile(x)


def _stochastic_tile(x, u, dtype):
    if dtype == "int4":
        return _int4_stochastic_tile(x, u)
    return _fp4_stochastic_tile(x, u)


def _shared_scale_tile(tile):
    """Per-32-group scale X for a (R, C) tile; returns (R, C) broadcast X."""
    r, c = tile.shape
    grouped = tile.reshape(r, c // ref.MX_BLOCK, ref.MX_BLOCK)
    m = jnp.max(jnp.abs(grouped), axis=-1, keepdims=True)
    _, e2 = jnp.frexp(jnp.where(m > 0, m, 1.0))
    e = jnp.where(m > 0, e2 - 1, 0) - ref.FP4_EMAX
    e = jnp.where(m > 0, e, ref.SCALE_EMIN)
    x = ref.exact_pow2(e)
    return jnp.broadcast_to(x, grouped.shape).reshape(r, c)


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------


def _qdq_nr_kernel(x_ref, o_ref, *, dtype: str = "fp4"):
    """Algorithm 1 (biased OCP MX quantization), qdq, one VMEM tile."""
    tile = x_ref[...]
    x = _shared_scale_tile(tile)
    o_ref[...] = _nearest_tile(jnp.clip(tile / x, -8.0, 8.0), dtype) * x


def _qdq_sr_kernel(x_ref, u_ref, o_ref, *, prescale: bool, dtype: str = "fp4"):
    """Algorithm 2 (unbiased: 3/4 pre-scale + SR), qdq, one VMEM tile."""
    tile = x_ref[...]
    u = u_ref[...]
    x = _shared_scale_tile(tile)
    scaled = tile / x
    if prescale:
        scaled = scaled * 0.75
    o_ref[...] = _stochastic_tile(scaled, u, dtype) * x


# ---------------------------------------------------------------------------
# Public wrappers (pallas_call builders)
# ---------------------------------------------------------------------------


def _tile_grid(shape, blk_r, blk_c):
    r, c = shape
    br = pick_block(r, blk_r)
    bc = pick_block(c // ref.MX_BLOCK, max(blk_c // ref.MX_BLOCK, 1)) * ref.MX_BLOCK
    return (r // br, c // bc), (br, bc)


def mxfp4_qdq_nr(
    x: jnp.ndarray,
    blk_r: int = DEFAULT_BLK_R,
    blk_c: int = DEFAULT_BLK_C,
    dtype: str = "fp4",
) -> jnp.ndarray:
    """Pallas MX qdq, Algorithm 1 (nearest rounding). x: (..., C), 32|C.
    ``dtype`` selects the base element format: "fp4" (E2M1) or "int4"."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    grid, (br, bc) = _tile_grid(x2.shape, blk_r, blk_c)
    out = pl.pallas_call(
        functools.partial(_qdq_nr_kernel, dtype=dtype),
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        interpret=True,
    )(x2)
    return out.reshape(shape)


def mxfp4_qdq_sr(
    x: jnp.ndarray,
    u: jnp.ndarray,
    prescale: bool = True,
    blk_r: int = DEFAULT_BLK_R,
    blk_c: int = DEFAULT_BLK_C,
    dtype: str = "fp4",
) -> jnp.ndarray:
    """Pallas MX qdq, Algorithm 2 (3/4 pre-scale + stochastic rounding).

    ``u`` is uniform-[0,1) dither of the same shape. Output is an unbiased
    estimate of (3/4)·x (of x when ``prescale=False``, modulo clip bias).
    ``dtype`` selects "fp4" or "int4" base elements.
    """
    assert x.shape == u.shape
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    u2 = u.reshape(-1, shape[-1])
    grid, (br, bc) = _tile_grid(x2.shape, blk_r, blk_c)
    kernel = functools.partial(_qdq_sr_kernel, prescale=prescale, dtype=dtype)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        interpret=True,
    )(x2, u2)
    return out.reshape(shape)
