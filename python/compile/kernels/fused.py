"""Fused RHT + MXFP4-quantize Pallas kernel (the §4.2 prologue fusion).

The paper notes that an efficient implementation fuses Algorithm 3's
lines 3-6 (the blockwise RHT) into lines 7-8 (the MXFP4 GEMM) "reducing
costly memory accesses". This kernel is that fusion's prologue half: each
(BLK_R, g) operand tile is read from HBM once, hit with the resident
diag(S)·H_g MXU tile, quantized to MXFP4 (Algorithm 1 or 2) in-register,
and only the qdq result is written back — IO O(bn), never materializing
the transformed high-precision operand.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .mxfp4 import (
    _nearest_tile,
    _shared_scale_tile,
    _stochastic_tile,
    pick_block,
)

# See rht.py: fat tiles keep the grid short; x-tile + u-tile + out-tile at
# (2048, g<=256) stay under 6 MB of VMEM.
DEFAULT_BLK_R = 2048


def _rht_qdq_nr_kernel(x_ref, m_ref, o_ref, *, dtype: str = "fp4"):
    t = jnp.dot(x_ref[...], m_ref[...], preferred_element_type=jnp.float32)
    x = _shared_scale_tile(t)
    o_ref[...] = _nearest_tile(jnp.clip(t / x, -8.0, 8.0), dtype) * x


def _rht_qdq_sr_kernel(x_ref, m_ref, u_ref, o_ref, *, prescale: bool, dtype: str = "fp4"):
    t = jnp.dot(x_ref[...], m_ref[...], preferred_element_type=jnp.float32)
    u = u_ref[...]
    x = _shared_scale_tile(t)
    scaled = t / x
    if prescale:
        scaled = scaled * 0.75
    o_ref[...] = _stochastic_tile(scaled, u, dtype) * x


def rht_qdq(
    x: jnp.ndarray,
    sign: jnp.ndarray,
    u: jnp.ndarray | None = None,
    *,
    stochastic: bool = True,
    prescale: bool = True,
    blk_r: int = DEFAULT_BLK_R,
    dtype: str = "fp4",
) -> jnp.ndarray:
    """Fused blockwise-RHT + MXFP4 qdq along the last axis.

    Equivalent to ``ref.quantize_mx_{sr,nr}(ref.rht_last_axis(x, sign))``
    but with one HBM round-trip. ``u`` (uniform [0,1), same shape as x) is
    required when ``stochastic=True``. g = len(sign) must be a multiple of
    32 so MX groups tile the transformed chunks exactly.
    """
    g = sign.shape[0]
    assert g % ref.MX_BLOCK == 0, g
    shape = x.shape
    assert shape[-1] % g == 0, (shape, g)
    m = ref.rht_matrix(sign)
    x2 = x.reshape(-1, g)
    rows = x2.shape[0]
    br = pick_block(rows, blk_r)
    if stochastic:
        assert u is not None and u.shape == x.shape
        u2 = u.reshape(-1, g)
        kernel = functools.partial(_rht_qdq_sr_kernel, prescale=prescale, dtype=dtype)
        out = pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
            grid=(rows // br,),
            in_specs=[
                pl.BlockSpec((br, g), lambda i: (i, 0)),
                pl.BlockSpec((g, g), lambda i: (0, 0)),
                pl.BlockSpec((br, g), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((br, g), lambda i: (i, 0)),
            interpret=True,
        )(x2, m, u2)
    else:
        out = pl.pallas_call(
            functools.partial(_rht_qdq_nr_kernel, dtype=dtype),
            out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
            grid=(rows // br,),
            in_specs=[
                pl.BlockSpec((br, g), lambda i: (i, 0)),
                pl.BlockSpec((g, g), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((br, g), lambda i: (i, 0)),
            interpret=True,
        )(x2, m)
    return out.reshape(shape)
