"""Precision-recipe registry.

A recipe pins (a) the forward-pass mixed precision and (b) the backward
MXFP4 construction for decoder linear layers — exactly the axes Table 2 /
Figures 3-9 sweep. Recipes are frozen (hashable) so they can be
``nondiff_argnums`` of the custom_vjp linear layer and baked into one AOT
artifact each.
"""

from __future__ import annotations

import dataclasses

FWD_PRECISIONS = ("f32", "bf16", "fp8")
BWD_MODES = ("exact", "nr", "sr", "rht", "rht_sr")


@dataclasses.dataclass(frozen=True)
class Recipe:
    """Precision recipe for decoder linear layers.

    fwd:       forward GEMM operand precision ("bf16" is the paper's
               baseline; "fp8" reproduces appendix §6.1; "f32" is a debug
               path).
    bwd_mode:  MXFP4 construction for the two backward GEMMs
               ("exact" = BF16-backward baseline; "nr"/"sr"/"rht"/"rht_sr"
               per Table 2's ablations).
    g:         RHT block size (Table 4 sweeps 32..256). 32 | g <= 256.
    impl:      "pallas" routes quantize+RHT through the L1 kernels,
               "ref" through the pure-jnp oracle (identical numerics).
    """

    fwd: str = "bf16"
    bwd_mode: str = "rht_sr"
    g: int = 64
    impl: str = "pallas"
    # base MX element format for the backward GEMMs: "fp4" (E2M1, the
    # paper's headline) or "int4" (the "also applies to MXINT4" extension)
    dtype: str = "fp4"

    def __post_init__(self):
        assert self.fwd in FWD_PRECISIONS, self.fwd
        assert self.bwd_mode in BWD_MODES, self.bwd_mode
        assert self.g % 32 == 0 and 32 <= self.g <= 1024, self.g

    @property
    def name(self) -> str:
        parts = [self.fwd, self.bwd_mode]
        if self.dtype != "fp4":
            parts.insert(1, self.dtype)
        if "rht" in self.bwd_mode:
            parts.append(f"g{self.g}")
        return "_".join(parts)


# The recipe set of Table 2 (BF16 forward; backward ablations).
TABLE2_RECIPES = {
    "bf16": Recipe(fwd="bf16", bwd_mode="exact"),
    "mxfp4": Recipe(fwd="bf16", bwd_mode="nr"),
    "mxfp4_sr": Recipe(fwd="bf16", bwd_mode="sr"),
    "mxfp4_rht": Recipe(fwd="bf16", bwd_mode="rht", g=64),
    "mxfp4_rht_sr": Recipe(fwd="bf16", bwd_mode="rht_sr", g=64),
}

# Table 4: RHT block-size ablation.
TABLE4_RECIPES = {
    f"mxfp4_rht_sr_g{g}": Recipe(fwd="bf16", bwd_mode="rht_sr", g=g)
    for g in (32, 64, 128, 256)
}

# §3 "our analysis also applies to other low precision datatypes": MXINT4.
MXINT4_RECIPES = {
    "mxint4_rht_sr": Recipe(fwd="bf16", bwd_mode="rht_sr", g=64, dtype="int4"),
    "mxint4": Recipe(fwd="bf16", bwd_mode="nr", dtype="int4"),
}

# Appendix §6.1 (Figures 7-9): FP8 forward + MXFP4 backward.
FP8_RECIPES = {
    "fp8_fwd_bf16_bwd": Recipe(fwd="fp8", bwd_mode="exact"),
    "fp8_fwd_mxfp4_rht_sr": Recipe(fwd="fp8", bwd_mode="rht_sr", g=64),
}

ALL_RECIPES = {**TABLE2_RECIPES, **TABLE4_RECIPES, **MXINT4_RECIPES, **FP8_RECIPES}


def get(name: str) -> Recipe:
    if name not in ALL_RECIPES:
        raise KeyError(f"unknown recipe {name!r}; known: {sorted(ALL_RECIPES)}")
    return ALL_RECIPES[name]
