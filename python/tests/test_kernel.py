"""Pallas kernels vs the pure-jnp oracle (ref.py) — the core L1 signal.

Hypothesis sweeps shapes, scales, and group sizes; every comparison is
exact (max abs diff == 0) because kernel and oracle implement the same
deterministic arithmetic on the same inputs (SR dither noise is an
explicit input, not hidden state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused, mxfp4, ref, rht

jax.config.update("jax_platform_name", "cpu")


def rnd(seed: int, shape, scale: float = 1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


def uni(seed: int, shape):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape)


def sign_vec(seed: int, g: int):
    return jax.random.rademacher(jax.random.PRNGKey(seed), (g,), dtype=jnp.float32)


def max_diff(a, b) -> float:
    return float(jnp.max(jnp.abs(a - b)))


# ---------------------------------------------------------------------------
# quantizer kernels vs oracle
# ---------------------------------------------------------------------------

shape_strategy = st.tuples(
    st.integers(min_value=1, max_value=96),
    st.sampled_from([32, 64, 96, 128, 256]),
)


@settings(max_examples=25, deadline=None)
@given(shape=shape_strategy, scale=st.sampled_from([1e-4, 0.1, 1.0, 37.0, 1e4]), seed=st.integers(0, 2**16))
def test_qdq_nr_matches_ref(shape, scale, seed):
    x = rnd(seed, shape, scale)
    assert max_diff(mxfp4.mxfp4_qdq_nr(x), ref.quantize_mx_nr(x)) == 0.0


@settings(max_examples=25, deadline=None)
@given(shape=shape_strategy, scale=st.sampled_from([1e-3, 1.0, 123.0]), seed=st.integers(0, 2**16))
def test_qdq_sr_matches_ref(shape, scale, seed):
    x = rnd(seed, shape, scale)
    u = uni(seed + 1, shape)
    assert max_diff(mxfp4.mxfp4_qdq_sr(x, u), ref.quantize_mx_sr(x, u)) == 0.0


@settings(max_examples=10, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**16))
def test_qdq_sr_noprescale_matches_ref(shape, seed):
    x = rnd(seed, shape, 2.0)
    u = uni(seed + 1, shape)
    got = mxfp4.mxfp4_qdq_sr(x, u, prescale=False)
    want = ref.quantize_mx_sr(x, u, prescale=False)
    assert max_diff(got, want) == 0.0


def test_qdq_nr_ties_to_even():
    # Exact midpoints of the FP4 grid, pre-scaled so X = 1 (max element 4.0).
    row = [0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0, 4.0] + [0.0] * 24
    x = jnp.asarray([row], dtype=jnp.float32)
    got = mxfp4.mxfp4_qdq_nr(x)[0, :8]
    want = jnp.asarray([0.0, 1.0, 1.0, 2.0, 2.0, 4.0, 4.0, 4.0])
    assert max_diff(got, want) == 0.0
    assert max_diff(got, ref.quantize_mx_nr(x)[0, :8]) == 0.0


def test_qdq_zero_block_is_zero():
    x = jnp.zeros((4, 64))
    assert max_diff(mxfp4.mxfp4_qdq_nr(x), jnp.zeros_like(x)) == 0.0
    u = uni(0, x.shape)
    assert max_diff(mxfp4.mxfp4_qdq_sr(x, u), jnp.zeros_like(x)) == 0.0


def test_qdq_output_on_fp4_grid():
    """Every qdq output must be exactly X * (an FP4 grid point)."""
    x = rnd(7, (16, 128), 3.0)
    q = np.asarray(mxfp4.mxfp4_qdq_nr(x))
    g = np.asarray(x).reshape(16, 4, 32)
    m = np.abs(g).max(axis=-1, keepdims=True)
    e = np.floor(np.log2(np.where(m > 0, m, 1.0))).astype(np.int32) - 2
    scale = np.exp2(e).astype(np.float32)
    ratio = q.reshape(16, 4, 32) / scale
    grid = set(ref.FP4_GRID.tolist()) | set((-ref.FP4_GRID).tolist())
    assert all(float(v) in grid for v in ratio.flatten())


# ---------------------------------------------------------------------------
# RHT kernels vs oracle
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=64),
    g=st.sampled_from([32, 64, 128, 256]),
    chunks=st.integers(min_value=1, max_value=4),
    seed=st.integers(0, 2**16),
)
def test_rht_matches_ref(rows, g, chunks, seed):
    x = rnd(seed, (rows, g * chunks))
    s = sign_vec(seed + 1, g)
    assert max_diff(rht.rht_last_axis(x, s), ref.rht_last_axis(x, s)) == 0.0


@settings(max_examples=15, deadline=None)
@given(g=st.sampled_from([32, 64, 128]), seed=st.integers(0, 2**16))
def test_fused_rht_qdq_sr_matches_composed(g, seed):
    x = rnd(seed, (32, g * 2), 2.0)
    u = uni(seed + 1, x.shape)
    s = sign_vec(seed + 2, g)
    got = fused.rht_qdq(x, s, u, stochastic=True)
    want = ref.quantize_mx_sr(ref.rht_last_axis(x, s), u)
    assert max_diff(got, want) == 0.0


@settings(max_examples=15, deadline=None)
@given(g=st.sampled_from([32, 64, 128]), seed=st.integers(0, 2**16))
def test_fused_rht_qdq_nr_matches_composed(g, seed):
    x = rnd(seed, (24, g * 3), 0.5)
    s = sign_vec(seed + 2, g)
    got = fused.rht_qdq(x, s, stochastic=False)
    want = ref.quantize_mx_nr(ref.rht_last_axis(x, s))
    assert max_diff(got, want) == 0.0


# ---------------------------------------------------------------------------
# kernels survive jit + lowering (the AOT path)
# ---------------------------------------------------------------------------


def test_kernels_jit_and_lower():
    @jax.jit
    def f(x, u, s):
        return fused.rht_qdq(x, s, u, stochastic=True)

    x = rnd(3, (8, 64))
    u = uni(4, x.shape)
    s = sign_vec(5, 64)
    out = f(x, u, s)
    assert out.shape == x.shape
    # lowering to stablehlo text must succeed (what aot.py does)
    txt = str(jax.jit(f).lower(x, u, s).compiler_ir("stablehlo"))
    assert "func" in txt


def test_pick_block():
    assert mxfp4.pick_block(256, 128) == 128
    assert mxfp4.pick_block(37, 128) == 37
    assert mxfp4.pick_block(96, 64) == 48 or 96 % mxfp4.pick_block(96, 64) == 0
    for n in [1, 2, 7, 24, 100, 1024]:
        b = mxfp4.pick_block(n, 128)
        assert n % b == 0 and b <= max(n, 1)


# ---------------------------------------------------------------------------
# MXINT4 kernel variants
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(shape=shape_strategy, scale=st.sampled_from([0.1, 1.0, 50.0]), seed=st.integers(0, 2**16))
def test_int4_qdq_nr_matches_ref(shape, scale, seed):
    x = rnd(seed, shape, scale)
    got = mxfp4.mxfp4_qdq_nr(x, dtype="int4")
    want = ref.quantize_mxint_nr(x)
    assert max_diff(got, want) == 0.0


@settings(max_examples=15, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**16))
def test_int4_qdq_sr_matches_ref(shape, seed):
    x = rnd(seed, shape, 2.0)
    u = uni(seed + 1, shape)
    got = mxfp4.mxfp4_qdq_sr(x, u, dtype="int4")
    want = ref.quantize_mxint_sr(x, u)
    assert max_diff(got, want) == 0.0


@settings(max_examples=10, deadline=None)
@given(g=st.sampled_from([32, 64]), seed=st.integers(0, 2**16))
def test_int4_fused_rht_qdq_matches_composed(g, seed):
    x = rnd(seed, (16, g * 2), 1.5)
    u = uni(seed + 1, x.shape)
    s = sign_vec(seed + 2, g)
    got = fused.rht_qdq(x, s, u, stochastic=True, dtype="int4")
    want = ref.quantize_mxint_sr(ref.rht_last_axis(x, s), u)
    assert max_diff(got, want) == 0.0
