"""L2 model tests: shapes, gradients, recipe plumbing, scan equivalence."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, mxgemm, recipes
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = model.CONFIGS["test"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def batch():
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, CFG.seq_len), 0, CFG.vocab)
    labs = jax.random.randint(jax.random.PRNGKey(2), (2, CFG.seq_len), 0, CFG.vocab)
    return toks, labs


def test_param_shapes_and_count(params):
    shapes = model.param_shapes(CFG)
    assert set(params.keys()) == set(shapes.keys())
    for n, s in shapes.items():
        assert params[n].shape == s, n
    assert CFG.param_count() == sum(int(np.prod(s)) for s in shapes.values())


def test_forward_shapes(params, batch):
    toks, _ = batch
    logits = model.forward(params, toks, jnp.uint32(0), CFG, recipes.get("bf16"))
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_near_uniform_at_init(params, batch):
    toks, labs = batch
    loss = model.loss_fn(params, toks, labs, jnp.uint32(0), CFG, recipes.get("bf16"))
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_causality(params):
    """Changing a future token must not change past logits."""
    toks = jnp.zeros((1, CFG.seq_len), jnp.int32)
    toks2 = toks.at[0, -1].set(42)
    r = recipes.get("bf16")
    l1 = model.forward(params, toks, jnp.uint32(0), CFG, r)
    l2 = model.forward(params, toks2, jnp.uint32(0), CFG, r)
    np.testing.assert_array_equal(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]))
    assert not np.allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]))


def test_train_step_outputs(params, batch):
    toks, labs = batch
    out = model.train_step(params, toks, labs, jnp.uint32(3), CFG, recipes.get("mxfp4_rht_sr"))
    names = list(model.param_shapes(CFG).keys())
    assert len(out) == 1 + len(names)
    for g, n in zip(out[1:], names):
        assert g.shape == params[n].shape, n
        assert bool(jnp.all(jnp.isfinite(g))), n


def test_bf16_grads_close_to_f32(params, batch):
    """The bf16 recipe's gradient should approximate the exact-f32 one."""
    toks, labs = batch
    f32 = recipes.Recipe(fwd="f32", bwd_mode="exact")
    bf16 = recipes.get("bf16")
    g_f32 = model.train_step(params, toks, labs, jnp.uint32(0), CFG, f32)[1:]
    g_bf = model.train_step(params, toks, labs, jnp.uint32(0), CFG, bf16)[1:]
    for a, b in zip(g_f32, g_bf):
        na, nb = float(jnp.linalg.norm(a)), float(jnp.linalg.norm(b))
        if na > 1e-6:
            rel = float(jnp.linalg.norm(a - b)) / na
            assert rel < 0.15, (na, nb, rel)


def test_mxfp4_grads_are_noisy_but_correlated(params, batch):
    """MXFP4 backward gradients point the same way as exact ones."""
    toks, labs = batch
    exact = model.train_step(params, toks, labs, jnp.uint32(0), CFG, recipes.get("bf16"))[1:]
    mx = model.train_step(params, toks, labs, jnp.uint32(0), CFG, recipes.get("mxfp4_rht_sr"))[1:]
    for a, b in zip(exact, mx):
        na, nb = float(jnp.linalg.norm(a)), float(jnp.linalg.norm(b))
        if na < 1e-6:
            continue
        cos = float(jnp.vdot(a, b)) / (na * nb)
        assert cos > 0.6, cos


def test_seed_changes_mx_grads_not_bf16(params, batch):
    toks, labs = batch
    r = recipes.get("mxfp4_rht_sr")
    g1 = model.train_step(params, toks, labs, jnp.uint32(1), CFG, r)
    g2 = model.train_step(params, toks, labs, jnp.uint32(2), CFG, r)
    assert not np.array_equal(np.asarray(g1[1]), np.asarray(g2[1]))
    b = recipes.get("bf16")
    h1 = model.train_step(params, toks, labs, jnp.uint32(1), CFG, b)
    h2 = model.train_step(params, toks, labs, jnp.uint32(2), CFG, b)
    np.testing.assert_array_equal(np.asarray(h1[1]), np.asarray(h2[1]))


def test_eval_and_logits_consistent(params, batch):
    toks, labs = batch
    r = recipes.get("bf16")
    (loss,) = model.eval_step(params, toks, labs, CFG, r)
    (logits,) = model.logits_fn(params, toks, CFG, r)
    logp = jax.nn.log_softmax(logits, axis=-1)
    manual = -jnp.mean(jnp.take_along_axis(logp, labs[..., None], axis=-1))
    assert abs(float(loss) - float(manual)) < 1e-5


def test_fp8_fwd_recipe_runs(params, batch):
    toks, labs = batch
    r = recipes.get("fp8_fwd_mxfp4_rht_sr")
    out = model.train_step(params, toks, labs, jnp.uint32(0), CFG, r)
    assert np.isfinite(float(out[0]))


# ---------------------------------------------------------------------------
# mxgemm dispatch
# ---------------------------------------------------------------------------


def test_mxgemm_impls_agree_deterministic_modes():
    a = jax.random.normal(jax.random.PRNGKey(0), (16, 128))
    b = jax.random.normal(jax.random.PRNGKey(1), (128, 8))
    for mode in ["nr", "rht"]:
        key = jax.random.PRNGKey(7)
        c_ref = mxgemm.mx_matmul(a, b, mode=mode, g=64, key=key, impl="ref")
        c_pal = mxgemm.mx_matmul(a, b, mode=mode, g=64, key=key, impl="pallas")
        np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pal))


def test_mxgemm_sr_impls_statistically_agree():
    """SR paths draw noise differently per impl but share semantics: both
    must be unbiased estimates of the exact product."""
    a = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
    b = jax.random.normal(jax.random.PRNGKey(3), (64, 4))
    want = np.asarray(a @ b)
    for impl in ["ref", "pallas"]:
        keys = jax.random.split(jax.random.PRNGKey(4), 400)
        got = np.mean(
            [np.asarray(mxgemm.mx_matmul(a, b, mode="rht_sr", key=k, impl=impl)) for k in keys],
            axis=0,
        )
        np.testing.assert_allclose(got, want, atol=0.35)


def test_recipe_registry():
    assert recipes.get("bf16").bwd_mode == "exact"
    assert recipes.get("mxfp4_rht_sr").g == 64
    assert recipes.get("mxfp4_rht_sr_g128").g == 128
    assert recipes.get("fp8_fwd_mxfp4_rht_sr").fwd == "fp8"
    with pytest.raises(KeyError):
        recipes.get("nope")
    names = {r.name for r in recipes.ALL_RECIPES.values()}
    assert len(names) >= 8  # distinct recipe identities
