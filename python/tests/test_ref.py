"""Properties of the reference oracle itself: the paper's Lemma 3.1
(unbiasedness), Theorem 3.2 (RHT variance reduction), the §3.1 clipping
bias of Algorithm 1, and structural invariants (orthogonality, scales).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rnd(seed, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


# ---------------------------------------------------------------------------
# FP4 grid / rounding primitives
# ---------------------------------------------------------------------------


def test_fp4_grid_is_e2m1():
    # E2M1, bias 1: subnormals {0, 0.5}; normals (1+M/2)*2^(E-1), E=1..3
    want = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]
    assert ref.FP4_GRID.tolist() == want


def test_fp4_nearest_idempotent_on_grid():
    pts = jnp.asarray(np.concatenate([ref.FP4_GRID, -ref.FP4_GRID]))
    assert float(jnp.max(jnp.abs(ref.fp4_nearest(pts) - pts))) == 0.0


def test_fp4_nearest_saturates():
    x = jnp.asarray([100.0, -100.0, 7.0, -6.5])
    got = ref.fp4_nearest(x)
    assert got.tolist() == [6.0, -6.0, 6.0, -6.0]


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_fp4_nearest_error_bounded(seed):
    """NR error is at most half the local gap (gaps: .5 below 2, 1 to 4, 2 to 6)."""
    x = jax.random.uniform(jax.random.PRNGKey(seed), (256,), minval=-6.0, maxval=6.0)
    q = ref.fp4_nearest(x)
    err = jnp.abs(q - x)
    gap_half = jnp.where(jnp.abs(x) <= 2.0, 0.25, jnp.where(jnp.abs(x) <= 4.0, 0.5, 1.0))
    assert bool(jnp.all(err <= gap_half + 1e-6))


def test_fp4_stochastic_unbiased_scalar():
    """E[SR(x)] == x on a dense u-grid (exact expectation by quadrature)."""
    for x in [0.1, 0.6, 1.1, 1.7, 2.4, 3.3, 4.7, 5.9, -2.2]:
        u = jnp.linspace(0.0, 1.0, 20001)[:-1]  # [0, 1)
        xs = jnp.full_like(u, x)
        mean = float(jnp.mean(ref.fp4_stochastic(xs, u)))
        assert abs(mean - x) < 2e-4, (x, mean)


def test_fp4_stochastic_on_grid_is_exact():
    pts = jnp.asarray(np.concatenate([ref.FP4_GRID, -ref.FP4_GRID]))
    u = jnp.full(pts.shape, 0.7)
    assert float(jnp.max(jnp.abs(ref.fp4_stochastic(pts, u) - pts))) == 0.0


def test_floor_log2_exact_on_powers_of_two():
    e = np.arange(-126, 128)
    m = jnp.asarray(np.exp2(e.astype(np.float64)).astype(np.float32))
    assert bool(jnp.all(ref.floor_log2(m) == jnp.asarray(e)))
    # just below a power of two floors down
    assert int(ref.floor_log2(jnp.float32(3.9999))) == 1
    assert int(ref.floor_log2(jnp.float32(4.0))) == 2


def test_exact_pow2():
    e = np.arange(-126, 128)
    want = jnp.asarray(np.exp2(e.astype(np.float64)).astype(np.float32))
    assert bool(jnp.all(ref.exact_pow2(jnp.asarray(e)) == want))


# ---------------------------------------------------------------------------
# shared scale (Algorithm 1 lines 1-2)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.sampled_from([1e-6, 1e-2, 1.0, 1e3]))
def test_shared_scale_normalizes_below_8(seed, scale):
    v = rnd(seed, (8, 4, 32), scale)
    x = ref.shared_scale(v)
    scaled = jnp.abs(v) / x
    assert bool(jnp.all(scaled < 8.0 + 1e-5))
    # and the max element is >= 4 (shared exp is tight)
    m = jnp.max(scaled, axis=-1)
    assert bool(jnp.all(m >= 4.0 - 1e-5))


def test_shared_scale_zero_block():
    v = jnp.zeros((1, 1, 32))
    x = ref.shared_scale(v)
    assert float(x[0, 0, 0]) == 2.0 ** -126  # FTZ-safe scale floor


# ---------------------------------------------------------------------------
# Algorithm 1 bias (§3.1) and Algorithm 2 unbiasedness (Lemma 3.1)
# ---------------------------------------------------------------------------


def test_alg1_clipping_bias_exists():
    """§3.1: ~3% of Gaussian entries land in (6, 8] after scaling and clip."""
    v = rnd(0, (4096, 32), 1.0)
    x = ref.shared_scale(v.reshape(4096, 1, 32))
    scaled = jnp.abs(v.reshape(4096, 1, 32)) / x
    frac_clipped = float(jnp.mean(scaled > 6.0))
    assert 0.005 < frac_clipped < 0.10, frac_clipped
    # and Algorithm 1 therefore under-estimates magnitudes on average
    q = ref.quantize_mx_nr(v)
    bias = float(jnp.mean(jnp.abs(q)) - jnp.mean(jnp.abs(v)))
    assert bias < 0.0


def test_alg2_unbiased_three_quarters():
    """Lemma 3.1: E[Alg2(v)] = (3/4) v — estimated over many dither draws."""
    v = rnd(1, (32,), 2.0)
    n = 4000
    vv = jnp.broadcast_to(v, (n, 32))
    u = jax.random.uniform(jax.random.PRNGKey(2), (n, 32))
    q = ref.quantize_mx_sr(vv, u)
    est = q.mean(axis=0)
    # standard error of the mean: gap*X/sqrt(12)/sqrt(n); gap*X <= 2 here
    np.testing.assert_allclose(np.asarray(est), 0.75 * np.asarray(v), atol=0.08)


def test_alg2_never_clips():
    """3/4 pre-scale keeps all scaled magnitudes <= 6 (proof of Lemma 3.1)."""
    v = rnd(3, (512, 32), 10.0)
    x = ref.shared_scale(v.reshape(512, 1, 32))
    scaled = 0.75 * jnp.abs(v.reshape(512, 1, 32)) / x
    assert bool(jnp.all(scaled < 6.0 + 1e-5))


def test_mx_matmul_sr_unbiased():
    """Lemma 3.1 end-to-end: E[mx_matmul_sr(A,B)] ~= A@B after 16/9 rescale."""
    a = rnd(4, (4, 64))
    b = rnd(5, (64, 4))
    want = np.asarray(a @ b)
    n = 600
    keys = jax.random.split(jax.random.PRNGKey(6), n)
    got = np.mean(
        [np.asarray(ref.mx_matmul(a, b, mode="sr", key=k)) for k in keys], axis=0
    )
    # mean of n GEMMs: tolerance ~ 3 * std/sqrt(n)
    np.testing.assert_allclose(got, want, atol=0.25)


def test_mx_matmul_nr_biased():
    """Algorithm 1 is deterministic — repeated calls give the same (biased) C."""
    a = rnd(7, (8, 64), 2.0)
    b = rnd(8, (64, 8), 2.0)
    c1 = ref.mx_matmul(a, b, mode="nr")
    c2 = ref.mx_matmul(a, b, mode="nr")
    assert float(jnp.max(jnp.abs(c1 - c2))) == 0.0


# ---------------------------------------------------------------------------
# RHT properties (§3.2, Theorem 3.2)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(g=st.sampled_from([32, 64, 128, 256]), seed=st.integers(0, 2**16))
def test_rht_orthogonal(g, seed):
    s = jax.random.rademacher(jax.random.PRNGKey(seed), (g,), dtype=jnp.float32)
    m = ref.rht_matrix(s)
    err = float(jnp.max(jnp.abs(m @ m.T - jnp.eye(g))))
    assert err < 1e-5


@settings(max_examples=10, deadline=None)
@given(g=st.sampled_from([32, 64]), seed=st.integers(0, 2**16))
def test_rht_cancels_in_gemm(g, seed):
    """(HSa)·(HSb) == a·b — the transform is free inside the dot product."""
    s = jax.random.rademacher(jax.random.PRNGKey(seed), (g,), dtype=jnp.float32)
    a = rnd(seed + 1, (4, g * 2))
    b = rnd(seed + 2, (g * 2, 4))
    ta = ref.rht_last_axis(a, s)
    tb = ref.rht_last_axis(b.T, s).T
    err = float(jnp.max(jnp.abs(ta @ tb - a @ b)))
    assert err < 1e-3


def test_rht_norm_preserved():
    s = jax.random.rademacher(jax.random.PRNGKey(0), (64,), dtype=jnp.float32)
    x = rnd(1, (16, 256))
    t = ref.rht_last_axis(x, s)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(t), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )


def test_rht_concentrates_outliers():
    """Eq. 5: a spike vector becomes dense with ~‖x‖/sqrt(g) entries."""
    g = 128
    s = jax.random.rademacher(jax.random.PRNGKey(3), (g,), dtype=jnp.float32)
    x = jnp.zeros((1, g)).at[0, 17].set(10.0)  # worst case: single outlier
    t = ref.rht_last_axis(x, s)
    assert float(jnp.max(jnp.abs(t))) <= 10.0 / np.sqrt(g) + 1e-5


def test_theorem_3_2_variance_reduction():
    """SR-GEMM variance with RHT grows slower in b than without (Fig. 2)."""
    def gemm_var(b, use_rht, trials=200, seed=0):
        key = jax.random.PRNGKey(seed)
        ka, kb, ko = jax.random.split(key, 3)
        a = jax.random.normal(ka, (1, b))
        bb = jax.random.normal(kb, (b, 1))
        # inject outliers (p = 1%, scale 5) as in Fig. 2
        mask = jax.random.bernoulli(ko, 0.01, (1, b))
        a = jnp.where(mask, a * 5.0, a)
        mode = "rht_sr" if use_rht else "sr"
        keys = jax.random.split(jax.random.PRNGKey(seed + 1), trials)
        outs = jnp.stack(
            [ref.mx_matmul(a, bb, mode=mode, g=32, key=k)[0, 0] for k in keys]
        )
        return float(jnp.var(outs))

    v_plain_small, v_plain_big = gemm_var(64, False), gemm_var(1024, False)
    v_rht_small, v_rht_big = gemm_var(64, True), gemm_var(1024, True)
    growth_plain = v_plain_big / max(v_plain_small, 1e-12)
    growth_rht = v_rht_big / max(v_rht_small, 1e-12)
    assert growth_rht < growth_plain, (growth_rht, growth_plain)


# ---------------------------------------------------------------------------
# fp8 / bf16 qdq
# ---------------------------------------------------------------------------


def test_fp8_qdq_relative_error():
    x = rnd(0, (64, 64))
    rel = float(jnp.linalg.norm(ref.fp8_e4m3_qdq(x) - x) / jnp.linalg.norm(x))
    assert rel < 0.04  # appendix: ~0.3% output error; elementwise ~3%


def test_bf16_qdq_exact_on_bf16_values():
    x = jnp.asarray([1.0, 0.5, -2.0, 3.140625])
    assert float(jnp.max(jnp.abs(ref.bf16_qdq(x) - x))) == 0.0


# ---------------------------------------------------------------------------
# MXINT4 extension ("our analysis also applies to MXINT4", §3)
# ---------------------------------------------------------------------------


def test_int4_nearest_grid():
    x = jnp.asarray([3.2, 3.5, 2.5, -2.5, 100.0, -100.0, 0.4])
    got = ref.int4_nearest(x)
    assert got.tolist() == [3.0, 4.0, 2.0, -2.0, 7.0, -8.0, 0.0]


def test_int4_stochastic_unbiased():
    for x in [0.3, 1.7, -2.4, 6.9, -7.6]:
        u = jnp.linspace(0.0, 1.0, 10001)[:-1]
        mean = float(jnp.mean(ref.int4_stochastic(jnp.full_like(u, x), u)))
        assert abs(mean - x) < 1e-3, (x, mean)


def test_mxint_nr_outputs_integral_residuals():
    v = rnd(0, (8, 4, 32), 3.0).reshape(8, 128)
    q = ref.quantize_mxint_nr(v)
    g = ref._group(v, 32)
    x = ref.shared_scale(g)
    r = ref._group(q, 32) / x
    assert bool(jnp.all(r == jnp.round(r)))
    assert bool(jnp.all((r >= -8) & (r <= 7)))


def test_mxint_sr_unbiased_three_quarters():
    v = rnd(1, (32,), 2.0)
    n = 4000
    vv = jnp.broadcast_to(v, (n, 32))
    u = jax.random.uniform(jax.random.PRNGKey(2), (n, 32))
    q = ref.quantize_mxint_sr(vv, u)
    est = q.mean(axis=0)
    np.testing.assert_allclose(np.asarray(est), 0.75 * np.asarray(v), atol=0.06)


def test_mxint_vs_mxfp4_error_tradeoff():
    """INT4's uniform grid wins near the block max; FP4's fine rungs win
    near zero — the trade-off that motivates per-format recipes."""
    k = jax.random.PRNGKey(3)
    big = jax.random.uniform(k, (64, 32), minval=4.0, maxval=7.0)
    mse = lambda q, v: float(jnp.mean((q - v) ** 2))
    assert mse(ref.quantize_mxint_nr(big), big) < mse(ref.quantize_mx_nr(big), big)
    small = jax.random.normal(k, (64, 32)) * 0.2
    small = small.at[:, 0].set(6.0)
    assert mse(ref.quantize_mx_nr(small), small) < mse(ref.quantize_mxint_nr(small), small)


def test_mx_matmul_int4_modes():
    a = rnd(4, (8, 64))
    b = rnd(5, (64, 8))
    for mode in ["nr", "rht_sr"]:
        c = ref.mx_matmul(a, b, mode=mode, key=jax.random.PRNGKey(6), dtype="int4")
        rel = float(jnp.linalg.norm(c - a @ b) / jnp.linalg.norm(a @ b))
        assert rel < 0.6, (mode, rel)
