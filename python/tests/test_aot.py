"""AOT pipeline tests: HLO text emission, metadata ABI, constant fidelity."""

from __future__ import annotations

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model, recipes

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def emitted():
    d = tempfile.mkdtemp(prefix="aot_test_")
    aot.emit(d, "test", "mxfp4_rht_sr", "train", batch=2)
    aot.emit(d, "test", "bf16", "eval", batch=2)
    return d


def test_hlo_text_is_parseable_shape(emitted):
    text = open(os.path.join(emitted, "test_mxfp4_rht_sr_train.hlo.txt")).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_no_elided_constants(emitted):
    """The 0.5.1 parser zero-fills `constant({...})` — emission must print
    every constant in full (the bug class found during bring-up)."""
    for name in ["test_mxfp4_rht_sr_train", "test_bf16_eval"]:
        text = open(os.path.join(emitted, f"{name}.hlo.txt")).read()
        assert "{...}" not in text, f"{name} contains elided constants"


def test_metadata_abi(emitted):
    meta = json.load(open(os.path.join(emitted, "test_mxfp4_rht_sr_train.meta.json")))
    cfg = model.CONFIGS["test"]
    names = list(model.param_shapes(cfg).keys())
    assert [p["name"] for p in meta["params"]] == names
    assert meta["inputs"][0]["name"] == "seed"
    assert meta["inputs"][1]["shape"] == [2, cfg.seq_len]
    assert meta["outputs"][0]["name"] == "loss"
    assert len(meta["outputs"]) == 1 + len(names)
    assert meta["recipe"]["bwd_mode"] == "rht_sr"
    assert meta["param_count"] == cfg.param_count()


def test_train_artifact_arity_includes_unused_seed(emitted):
    """keep_unused=True: the bf16 eval takes exactly its ABI inputs; the
    train artifact keeps `seed` even for deterministic recipes."""
    d = emitted
    aot.emit(d, "test", "bf16", "train", batch=2)
    text = open(os.path.join(d, "test_bf16_train.hlo.txt")).read()
    # 3 + n_params parameters in the entry computation
    n_params = len(model.param_shapes(model.CONFIGS["test"]))
    entry = text[text.index("ENTRY") :]
    count = entry.count("parameter(")
    assert count == 3 + n_params, f"expected {3 + n_params} params, found {count}"


def test_abstract_args_match_kind():
    cfg = model.CONFIGS["test"]
    train = aot._abstract_args(cfg, 2, "train")
    assert len(train) == 3 + len(model.param_shapes(cfg))
    assert train[0].dtype == jnp.uint32
    ev = aot._abstract_args(cfg, 2, "eval")
    assert len(ev) == 2 + len(model.param_shapes(cfg))
    lg = aot._abstract_args(cfg, 2, "logits")
    assert len(lg) == 1 + len(model.param_shapes(cfg))
    with pytest.raises(ValueError):
        aot._abstract_args(cfg, 2, "bogus")


def test_golden_emission_roundtrips(tmp_path):
    aot.emit_golden(str(tmp_path))
    doc = json.load(open(tmp_path / "golden.json"))
    assert len(doc["quant_nr"]) == 5
    case = doc["quant_nr"][0]
    assert len(case["input"]) == len(case["qdq_nr"])
    assert len(doc["rht"]["sign"]) == 64
